//! Intent-based routing (paper Section 2.5.1).
//!
//! Clients express a scoring *intent* (tenant, geography, schema,
//! channel) — never a model/predictor name. Scoring rules are
//! evaluated **sequentially** (first match wins, selecting exactly one
//! *live* predictor); shadow rules are evaluated **in parallel**
//! (every match mirrors the request). Routing uses only request
//! metadata — no external lookups, no state — and the hot path is
//! genuinely lock-free: the active [`RoutingConfig`] lives in a
//! [`SnapCell`] (an `AtomicPtr`-based snapshot cell with writer-side
//! keep-alive reclamation), so [`Router::resolve`] performs one
//! wait-free snapshot load and zero mutex/rwlock acquisitions. Config
//! updates (`swap`) publish a complete new snapshot copy-on-write,
//! mirroring the stateless-pod rolling restart of Section 2.5.2:
//! every resolution sees either the old config or the new one in its
//! entirety, never a torn mixture. Targets are shared `Arc<str>`s, so
//! resolving allocates nothing beyond the (usually empty) shadow list.

use crate::config::{Intent, RoutingConfig};
use crate::util::swap::SnapCell;
use anyhow::{bail, Result};
use std::sync::Arc;

/// The outcome of routing one request. Predictor names are `Arc<str>`
/// clones of the config's own strings — refcount bumps, not `String`
/// allocations.
#[derive(Debug, Clone, PartialEq)]
pub struct Resolution {
    /// The single live predictor serving the client response.
    pub live: Arc<str>,
    /// Shadow predictors mirroring this request (may be empty).
    pub shadows: Vec<Arc<str>>,
    /// Index of the matched scoring rule (for observability, and for
    /// index-based target lookup in the engine snapshot).
    pub rule_index: usize,
}

/// Lock-free-on-read router with an atomically swappable config.
pub struct Router {
    config: SnapCell<RoutingConfig>,
}

impl Router {
    pub fn new(config: RoutingConfig) -> Self {
        Router {
            config: SnapCell::new(Arc::new(config)),
        }
    }

    /// Swap the routing configuration atomically (a "rolling update"
    /// in the single-process engine; the cluster-level rollout is
    /// simulated in `simulator::cluster`). In-flight resolutions keep
    /// the snapshot they already loaded; new ones see the new config.
    pub fn swap(&self, config: RoutingConfig) {
        self.config.store(Arc::new(config));
    }

    /// Snapshot the current configuration (wait-free).
    pub fn snapshot(&self) -> Arc<RoutingConfig> {
        self.config.load()
    }

    /// Identity of the current config snapshot, for cheap staleness
    /// checks by layers that compile routing into richer snapshots
    /// (see `coordinator::snapshot`). Never dereferenced.
    pub(crate) fn config_ptr(&self) -> *const RoutingConfig {
        self.config.peek()
    }

    /// Resolve an intent to live + shadow predictors against the
    /// current config. One snapshot load; no locks.
    pub fn resolve(&self, intent: &Intent) -> Result<Resolution> {
        Self::resolve_in(&self.config.load(), intent)
    }

    /// Resolve against an explicit config snapshot (used by the engine
    /// so routing and target lookup share one coherent snapshot).
    pub fn resolve_in(cfg: &RoutingConfig, intent: &Intent) -> Result<Resolution> {
        let mut live = None;
        for (i, rule) in cfg.scoring_rules.iter().enumerate() {
            if rule.condition.matches(intent) {
                live = Some((Arc::clone(&rule.target_predictor), i));
                break; // sequential: first match wins
            }
        }
        let Some((live, rule_index)) = live else {
            bail!(
                "no scoring rule matches intent (tenant='{}', geography='{}', \
                 schema='{}', channel='{}') — add a catch-all rule",
                intent.tenant,
                intent.geography,
                intent.schema,
                intent.channel
            );
        };
        // Parallel shadow evaluation: collect all matches, dedupe, and
        // never shadow onto the live predictor itself.
        let mut shadows: Vec<Arc<str>> = Vec::new();
        for rule in &cfg.shadow_rules {
            if rule.condition.matches(intent) {
                for t in &rule.target_predictors {
                    if *t != live && !shadows.contains(t) {
                        shadows.push(Arc::clone(t));
                    }
                }
            }
        }
        Ok(Resolution {
            live,
            shadows,
            rule_index,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Condition, ScoringRule, ShadowRule};
    use crate::prop_assert;
    use crate::util::prop;

    fn tenant_cond(t: &str) -> Condition {
        Condition {
            tenants: vec![t.to_string()],
            ..Condition::default()
        }
    }

    fn fig2_router() -> Router {
        Router::new(RoutingConfig {
            scoring_rules: vec![
                ScoringRule {
                    description: "Custom DAG for bank1".into(),
                    condition: tenant_cond("bank1"),
                    target_predictor: "bank1-predictor-v1".into(),
                },
                ScoringRule {
                    description: "US/LATAM fraud_v1".into(),
                    condition: Condition {
                        geographies: vec!["NAMER".into(), "LATAM".into()],
                        schemas: vec!["fraud_v1".into()],
                        ..Condition::default()
                    },
                    target_predictor: "america-predictor-v1".into(),
                },
                ScoringRule {
                    description: "catch-all".into(),
                    condition: Condition::default(),
                    target_predictor: "global-predictor-v3".into(),
                },
            ],
            shadow_rules: vec![ShadowRule {
                description: "shadow v2 for bank1".into(),
                condition: tenant_cond("bank1"),
                target_predictors: vec!["bank1-predictor-v2".into()],
            }],
        })
    }

    fn intent(t: &str, g: &str, s: &str) -> Intent {
        Intent {
            tenant: t.into(),
            geography: g.into(),
            schema: s.into(),
            channel: String::new(),
        }
    }

    fn shadow_names(res: &Resolution) -> Vec<&str> {
        res.shadows.iter().map(|s| &**s).collect()
    }

    #[test]
    fn paper_fig2_scenarios() {
        let r = fig2_router();
        // bank1 served by v1 AND shadowed to v2 (the paper's example).
        let res = r.resolve(&intent("bank1", "EMEA", "fraud_v1")).unwrap();
        assert_eq!(&*res.live, "bank1-predictor-v1");
        assert_eq!(shadow_names(&res), vec!["bank1-predictor-v2"]);
        assert_eq!(res.rule_index, 0);
        // US tenant with schema v1 routes to the regional predictor.
        let res = r.resolve(&intent("bankX", "NAMER", "fraud_v1")).unwrap();
        assert_eq!(&*res.live, "america-predictor-v1");
        assert!(res.shadows.is_empty());
        // Cold-start client falls through to the catch-all.
        let res = r.resolve(&intent("newbie", "APAC", "fraud_v2")).unwrap();
        assert_eq!(&*res.live, "global-predictor-v3");
        assert_eq!(res.rule_index, 2);
    }

    #[test]
    fn sequential_first_match_wins() {
        // bank1 in NAMER matches both rule 0 and rule 1; rule 0 wins.
        let r = fig2_router();
        let res = r.resolve(&intent("bank1", "NAMER", "fraud_v1")).unwrap();
        assert_eq!(&*res.live, "bank1-predictor-v1");
        assert_eq!(res.rule_index, 0);
        // Swapping rule order flips the winner: ordering is semantic.
        let mut cfg = r.snapshot().as_ref().clone();
        cfg.scoring_rules.swap(0, 1);
        let r2 = Router::new(cfg);
        let res = r2.resolve(&intent("bank1", "NAMER", "fraud_v1")).unwrap();
        assert_eq!(&*res.live, "america-predictor-v1");
        assert_eq!(res.rule_index, 0);
    }

    #[test]
    fn no_match_without_catch_all_errors() {
        let r = Router::new(RoutingConfig {
            scoring_rules: vec![ScoringRule {
                description: String::new(),
                condition: tenant_cond("only"),
                target_predictor: "p".into(),
            }],
            shadow_rules: vec![],
        });
        assert!(r.resolve(&intent("other", "", "")).is_err());
    }

    #[test]
    fn shadow_never_duplicates_live() {
        let mut cfg = fig2_router().snapshot().as_ref().clone();
        cfg.shadow_rules.push(ShadowRule {
            description: "self-shadow (misconfig)".into(),
            condition: tenant_cond("bank1"),
            target_predictors: vec!["bank1-predictor-v1".into(), "bank1-predictor-v2".into()],
        });
        let r = Router::new(cfg);
        let res = r.resolve(&intent("bank1", "", "")).unwrap();
        assert_eq!(&*res.live, "bank1-predictor-v1");
        // v2 appears once despite two matching shadow rules; live is
        // never mirrored onto itself.
        assert_eq!(shadow_names(&res), vec!["bank1-predictor-v2"]);
    }

    #[test]
    fn shadow_rules_fan_out_across_all_matches() {
        // Multiple matching shadow rules union their targets: one
        // request can mirror to several candidate predictors at once
        // (parallel evaluation, paper Fig. 2).
        let mut cfg = fig2_router().snapshot().as_ref().clone();
        cfg.shadow_rules.push(ShadowRule {
            description: "also trial v3".into(),
            condition: tenant_cond("bank1"),
            target_predictors: vec!["bank1-predictor-v3".into(), "bank1-predictor-v2".into()],
        });
        cfg.shadow_rules.push(ShadowRule {
            description: "other tenant only".into(),
            condition: tenant_cond("bank9"),
            target_predictors: vec!["never-matched".into()],
        });
        let r = Router::new(cfg);
        let res = r.resolve(&intent("bank1", "", "")).unwrap();
        assert_eq!(
            shadow_names(&res),
            vec!["bank1-predictor-v2", "bank1-predictor-v3"],
            "all matching shadow rules contribute, deduped, non-matching excluded"
        );
    }

    #[test]
    fn swap_changes_routing_atomically() {
        let r = fig2_router();
        let before = r.resolve(&intent("bank1", "", "")).unwrap();
        assert_eq!(&*before.live, "bank1-predictor-v1");
        // Promote v2 to live (the Fig. 3 lifecycle's final step).
        let mut cfg = r.snapshot().as_ref().clone();
        cfg.scoring_rules[0].target_predictor = "bank1-predictor-v2".into();
        cfg.shadow_rules.clear();
        r.swap(cfg);
        let after = r.resolve(&intent("bank1", "", "")).unwrap();
        assert_eq!(&*after.live, "bank1-predictor-v2");
        assert!(after.shadows.is_empty());
    }

    #[test]
    fn prop_resolution_is_deterministic_and_total_with_catch_all() {
        prop::check(100, |g| {
            let tenants = ["a", "b", "c", "d"];
            let r = fig2_router();
            let it = intent(
                tenants[g.usize(0..4)],
                ["NAMER", "EMEA"][g.usize(0..2)],
                ["fraud_v1", "fraud_v2"][g.usize(0..2)],
            );
            let x = r.resolve(&it).map_err(|e| e.to_string())?;
            let y = r.resolve(&it).map_err(|e| e.to_string())?;
            prop_assert!(x == y, "non-deterministic resolution");
            prop_assert!(!x.live.is_empty(), "empty live predictor");
            Ok(())
        });
    }

    #[test]
    fn concurrent_resolve_during_swap() {
        let r = Arc::new(fig2_router());
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    for _ in 0..2000 {
                        let res = r.resolve(&intent("bank1", "", "")).unwrap();
                        assert!(res.live.starts_with("bank1-predictor-v"));
                    }
                })
            })
            .collect();
        let writer = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                for i in 0..200 {
                    let mut cfg = r.snapshot().as_ref().clone();
                    cfg.scoring_rules[0].target_predictor =
                        format!("bank1-predictor-v{}", 1 + i % 2).into();
                    r.swap(cfg);
                }
            })
        };
        for h in readers {
            h.join().unwrap();
        }
        writer.join().unwrap();
    }

    #[test]
    fn swap_is_never_torn_under_contention() {
        // N resolver threads race M swapper iterations. Every config
        // version k keeps an invariant across its rules: the live
        // target and the shadow target carry the same version suffix.
        // A resolution mixing suffixes would prove a torn snapshot.
        fn versioned(k: u64) -> RoutingConfig {
            RoutingConfig {
                scoring_rules: vec![
                    ScoringRule {
                        description: "hot tenant".into(),
                        condition: tenant_cond("hot"),
                        target_predictor: format!("live-v{k}").into(),
                    },
                    ScoringRule {
                        description: "catch-all".into(),
                        condition: Condition::default(),
                        target_predictor: format!("global-v{k}").into(),
                    },
                ],
                shadow_rules: vec![ShadowRule {
                    description: "hot shadow".into(),
                    condition: tenant_cond("hot"),
                    target_predictors: vec![format!("shadow-v{k}").into()],
                }],
            }
        }
        let r = Arc::new(Router::new(versioned(0)));
        let hot = intent("hot", "", "");
        std::thread::scope(|s| {
            for _ in 0..4 {
                let r = Arc::clone(&r);
                let hot = hot.clone();
                s.spawn(move || {
                    for _ in 0..5_000 {
                        let res = r.resolve(&hot).unwrap();
                        let lv = res.live.rsplit('v').next().unwrap().to_string();
                        let sv = res.shadows[0].rsplit('v').next().unwrap().to_string();
                        assert_eq!(lv, sv, "torn snapshot: live {} vs shadow {}", res.live, res.shadows[0]);
                    }
                });
            }
            for _ in 0..2 {
                let r = Arc::clone(&r);
                s.spawn(move || {
                    for k in 1..=1_000u64 {
                        r.swap(versioned(k));
                    }
                });
            }
        });
    }
}
