//! The engine's data-plane snapshot: everything one request needs,
//! resolved ahead of time and published as a single immutable object.
//!
//! The seed implementation took two `RwLock`s per request (the
//! router's config lock plus the engine's lazy batcher map) and paid a
//! `HashMap` lookup per batcher acquisition. [`EngineSnapshot`]
//! removes all of it from the request path: the control plane
//! (`coordinator::deployment`) compiles the routing config, the
//! resolved predictor handles and the per-predictor dynamic batchers
//! into one snapshot and publishes it through a `SnapCell` (see
//! `util::swap`). `Engine::score` loads one snapshot per request —
//! wait-free — then routes by rule index straight to an
//! already-resolved [`PredictorEntry`]: no locks, no map probes, no
//! name cloning.
//!
//! Publication protocol (documented for operators in
//! docs/ARCHITECTURE.md):
//!
//! 1. the control plane mutates the registry and/or swaps the routing
//!    config (both copy-on-write);
//! 2. it rebuilds the snapshot from the *current* registry + routing,
//!    reusing live batchers by predictor name so in-flight batches
//!    keep coalescing across the swap;
//! 3. it publishes the snapshot atomically; requests that already
//!    loaded the old snapshot finish on it (valid by construction),
//!    new requests see the new world;
//! 4. batchers whose predictor left the registry are shut down after
//!    publication — stale-snapshot stragglers get a clean error, the
//!    same contract the seed had for decommissioned predictors.
//!
//! Direct `Router::swap` callers (tests, harnesses) are covered by a
//! staleness check in `Engine::score`: the snapshot records the
//! identity of the routing config it was compiled from, and a pointer
//! mismatch triggers a lazy republish before resolving.

use super::batcher::Batcher;
use super::predictor::Predictor;
use super::registry::PredictorRegistry;
use super::tenants::{TenantHandle, DEFAULT_NAME_SHARDS};
use crate::config::RoutingConfig;
use crate::datalake::{DataLake, PairRef};
use crate::lifecycle::{LifecycleHub, ScoreFeed};
use crate::metrics::{CounterHandle, TenantCounters};
use crate::util::slab::HandleSlab;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// Everything the commit phase of a scored event needs for one
/// `(tenant, predictor)` pair, resolved once and cached by
/// [`TenantHandle`] index on the predictor's entry: the data-lake
/// pair ref, the per-tenant event counter handle and the lifecycle
/// feed ring. With a warm route, committing an event performs zero
/// string hashing — every side effect is an array index or a direct
/// atomic (see `coordinator::tenants` for the interning story).
pub struct TenantRoute {
    /// Cached lake pair slot — `append_ref` skips both `&str` probes.
    pub pair: PairRef,
    /// The interned tenant handle — keys the engine's `tenant_events`
    /// counter slab and the lifecycle feed slab.
    pub tenant: TenantHandle,
    /// `tenant_events` counter, created on **first batch commit** —
    /// not at route build. The observable `scored_events` map must
    /// contain exactly the tenants the batch path accounted
    /// (`TenantCounters::handle` interns the slot at zero, and the
    /// verification harness checks full-map equality against the
    /// oracle), and routes are also built by the single-event and
    /// shadow paths, which never count.
    counter: std::sync::OnceLock<CounterHandle>,
    /// Feed-table epoch this route was resolved against; a mismatch
    /// with [`LifecycleHub::feeds_epoch`] invalidates `feed` only —
    /// the route rebuilds lazily on next use.
    feed_epoch: u64,
    /// The pair's lifecycle feed ring (`None`: unmanaged pair or
    /// lifecycle disabled).
    pub feed: Option<Arc<ScoreFeed>>,
}

impl TenantRoute {
    /// The tenant's `scored_events` counter: one slab intern on the
    /// first batch commit through this route, a plain atomic load
    /// afterwards. No string is hashed anywhere on this path.
    #[inline]
    pub fn counter(&self, tenant_events: &TenantCounters) -> &CounterHandle {
        self.counter
            .get_or_init(|| tenant_events.handle(self.tenant.index()))
    }
}

/// A predictor resolved for serving: the handle plus its dynamic
/// batcher. Shared (`Arc`) between consecutive snapshots, so a config
/// swap neither drains nor restarts batching.
pub struct PredictorEntry {
    pub predictor: Arc<Predictor>,
    pub batcher: Arc<Batcher>,
    /// Handle-indexed tenant routes on a sharded slab. Shared with the
    /// batcher across snapshot republishes (the entry itself is
    /// reused), so a routing swap does not cold-start the cache.
    /// Publishing a rebuilt route clones one constant-size segment of
    /// the handle's owning shard — the old copy-on-write `Vec`
    /// recloned every cached route per first touch, an O(tenants)
    /// republish that made onboarding storms quadratic.
    routes: HandleSlab<Arc<TenantRoute>>,
}

impl PredictorEntry {
    fn new(predictor: Arc<Predictor>, batcher: Arc<Batcher>) -> PredictorEntry {
        PredictorEntry {
            predictor,
            batcher,
            routes: HandleSlab::with_shards(DEFAULT_NAME_SHARDS),
        }
    }

    /// Resolve the commit route for `tenant` — one wait-free slab
    /// probe on the warm path. Cold (first sight of the tenant on this
    /// predictor, or the lifecycle feed table moved): re-resolves by
    /// name and publishes into the handle's slab slot.
    #[inline]
    pub fn route(
        &self,
        tenant: TenantHandle,
        tenant_name: &str,
        lake: &DataLake,
        hub: Option<&LifecycleHub>,
    ) -> Arc<TenantRoute> {
        let epoch = hub.map_or(0, |h| h.feeds_epoch());
        if let Some(r) = self.routes.get(tenant.index()) {
            if r.feed_epoch == epoch {
                return r;
            }
        }
        self.rebuild_route(tenant, tenant_name, epoch, lake, hub)
    }

    #[cold]
    fn rebuild_route(
        &self,
        tenant: TenantHandle,
        tenant_name: &str,
        epoch: u64,
        lake: &DataLake,
        hub: Option<&LifecycleHub>,
    ) -> Arc<TenantRoute> {
        let name = &*self.predictor.name;
        let route = Arc::new(TenantRoute {
            pair: lake.pair_ref(tenant_name, name),
            tenant,
            counter: std::sync::OnceLock::new(),
            feed_epoch: epoch,
            feed: hub.and_then(|h| h.feed_for(name, tenant)),
        });
        self.routes.set(tenant.index(), Arc::clone(&route));
        route
    }
}

/// One immutable world for the scoring data plane.
pub struct EngineSnapshot {
    /// The routing config this snapshot was compiled from. `Arc`
    /// identity doubles as the staleness token against the router.
    pub routing: Arc<RoutingConfig>,
    /// The registry generation this snapshot was compiled from (read
    /// *before* compiling, so a concurrent mutation makes the
    /// snapshot look stale rather than current). Lets the engine
    /// notice deploy/decommission calls made without a routing swap.
    pub registry_generation: u64,
    /// Scoring-rule index -> resolved live target (`None` when the
    /// rule names a predictor that is not deployed — surfaced as a
    /// routing error at request time, matching the seed's behavior).
    live: Vec<Option<Arc<PredictorEntry>>>,
    /// Every deployed predictor by name (shadow dispatch, admin).
    entries: HashMap<Arc<str>, Arc<PredictorEntry>>,
}

impl EngineSnapshot {
    /// Compile a snapshot from the current registry + routing config.
    /// Batchers are reused from `prev` by name when the predictor
    /// handle is unchanged; new predictors get fresh batchers.
    pub fn build(
        routing: Arc<RoutingConfig>,
        registry: &PredictorRegistry,
        prev: Option<&EngineSnapshot>,
        max_batch: usize,
        max_batch_delay: Duration,
    ) -> EngineSnapshot {
        let registry_generation = registry.generation();
        let mut entries: HashMap<Arc<str>, Arc<PredictorEntry>> = HashMap::new();
        for name in registry.names() {
            let Some(predictor) = registry.get(&name) else {
                continue; // raced a decommission; the next publish catches up
            };
            let reused = prev.and_then(|p| p.entries.get(name.as_str())).filter(|e| {
                Arc::ptr_eq(&e.predictor, &predictor)
            });
            let entry = match reused {
                Some(e) => Arc::clone(e),
                None => {
                    let batcher = Arc::new(Batcher::new(
                        Arc::clone(&predictor),
                        max_batch,
                        max_batch_delay,
                    ));
                    Arc::new(PredictorEntry::new(predictor, batcher))
                }
            };
            entries.insert(Arc::from(name.as_str()), entry);
        }
        let live = routing
            .scoring_rules
            .iter()
            .map(|r| entries.get(&*r.target_predictor).cloned())
            .collect();
        EngineSnapshot {
            routing,
            registry_generation,
            live,
            entries,
        }
    }

    /// The resolved live target of scoring rule `rule_index` — a plain
    /// vector index, no hashing, no locks.
    pub fn live_entry(&self, rule_index: usize) -> Option<&Arc<PredictorEntry>> {
        self.live.get(rule_index).and_then(|e| e.as_ref())
    }

    /// Look up a deployed predictor's entry by name (shadow path).
    pub fn entry(&self, name: &str) -> Option<&Arc<PredictorEntry>> {
        self.entries.get(name)
    }

    /// Entries of `self` whose predictor is absent from `next` —
    /// decommissioned between the two snapshots; their batchers are
    /// shut down after `next` is published.
    pub fn removed_entries(&self, next: &EngineSnapshot) -> Vec<Arc<PredictorEntry>> {
        self.entries
            .iter()
            .filter(|(name, _)| !next.entries.contains_key(&**name))
            .map(|(_, e)| Arc::clone(e))
            .collect()
    }

    /// Number of deployed predictors this snapshot serves.
    pub fn predictor_count(&self) -> usize {
        self.entries.len()
    }

    /// Deepest dynamic-batcher queue across deployed predictors right
    /// now — the pressure signal the ingress admission controller
    /// sheds on (wait-free gauge loads, no locks).
    pub fn max_batcher_depth(&self) -> usize {
        self.entries
            .values()
            .map(|e| e.batcher.depth())
            .max()
            .unwrap_or(0)
    }
}

/// Verification-plane introspection (`testkit`): the snapshot's entry
/// set and per-entry batcher accounting are otherwise unobservable
/// (the `entries` map is private by design — the data plane reaches it
/// only through resolved indices), but the oracle-diff harness needs
/// to assert the published world equals the oracle's model of it.
#[cfg(any(test, feature = "testkit"))]
impl EngineSnapshot {
    /// Sorted names of every deployed predictor entry in this
    /// snapshot.
    pub fn entry_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.entries.keys().map(|n| n.to_string()).collect();
        names.sort();
        names
    }

    /// Per-predictor dynamic-batcher stats (batches/events coalesced),
    /// sorted by name — the harness's conservation check: every
    /// single-path event (live or shadow mirror) passes through
    /// exactly one batcher.
    pub fn batcher_stats(&self) -> Vec<(String, super::batcher::BatcherStats)> {
        let mut out: Vec<(String, super::batcher::BatcherStats)> = self
            .entries
            .iter()
            .map(|(name, e)| (name.to_string(), e.batcher.stats()))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}
