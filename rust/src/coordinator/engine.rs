//! The serving engine: the stateless orchestration layer of Fig. 1.
//!
//! One `score()` call runs the full request path: intent routing ->
//! feature-store enrichment -> predictor DAG (expert inference on the
//! shared containers, `T^C`, `A`, tenant-specific `T^Q`) -> response,
//! while mirroring the request to every matching shadow predictor
//! asynchronously (shadow latency never blocks the live response) and
//! recording scores to the data lake.
//!
//! The live path is lock-free: one wait-free [`EngineSnapshot`] load
//! per request, then an index-based hop to the resolved predictor +
//! batcher (see `coordinator::snapshot` for the publication
//! protocol, and EXPERIMENTS.md "Contention" for the measured win
//! over the seed's two-`RwLock` path).

use super::batcher::Batcher;
use super::predictor::Predictor;
use super::registry::PredictorRegistry;
use super::router::{Resolution, Router};
use super::snapshot::EngineSnapshot;
use crate::config::{Intent, MuseConfig, QuantileMode};
use crate::datalake::DataLake;
use crate::featurestore::FeatureStore;
use crate::metrics::{Counters, LatencyHistogram};
use crate::runtime::ModelPool;
use crate::transforms::{QuantileMap, ReferenceDistribution};
use crate::util::swap::SnapCell;
use crate::util::threadpool::ThreadPool;
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One scoring request (the client payload).
#[derive(Debug, Clone)]
pub struct ScoreRequest {
    pub intent: Intent,
    /// Entity key for feature-store enrichment (e.g. card hash).
    pub entity: String,
    /// Payload features; enriched up to the model dim if partial.
    pub features: Vec<f32>,
}

/// The client-visible response.
#[derive(Debug, Clone, PartialEq)]
pub struct ScoreResponse {
    pub score: f64,
    pub predictor: String,
    /// Number of shadow predictors the request was mirrored to.
    pub shadow_count: usize,
}

pub struct Engine {
    pub router: Router,
    pub registry: PredictorRegistry,
    pub features: FeatureStore,
    pub lake: Arc<DataLake>,
    shadow_pool: ThreadPool,
    /// The compiled data-plane snapshot: routing + resolved predictor
    /// handles + per-predictor dynamic batchers, swapped atomically by
    /// the control plane. Batchers matter because concurrent
    /// single-event requests coalesce into one PJRT call — batch-256
    /// inference is ~80x cheaper per event than batch-1
    /// (EXPERIMENTS.md "Perf log", step 1).
    snapshot: SnapCell<EngineSnapshot>,
    max_batch: usize,
    max_batch_delay: Duration,
    pub live_latency: LatencyHistogram,
    pub counters: Counters,
    /// Quantile grid resolution (from the manifest).
    pub quantile_points: usize,
}

impl Engine {
    /// Build the engine from a validated config and a model pool.
    /// Predictors with `quantile: default` get the cold-start
    /// transformation installed by the control plane afterwards
    /// (`ControlPlane::fit_default_quantile`); they start at identity.
    pub fn build(config: &MuseConfig, pool: Arc<ModelPool>) -> Result<Engine> {
        config.validate()?;
        let quantile_points = pool.manifest().quantile_points;
        let registry = PredictorRegistry::new(pool);
        for pc in &config.predictors {
            let initial: Arc<QuantileMap> = match pc.quantile_mode {
                QuantileMode::Identity | QuantileMode::Custom | QuantileMode::Default => {
                    QuantileMap::identity(quantile_points.max(2))?.shared()
                }
            };
            registry
                .deploy(pc, initial)
                .with_context(|| format!("deploy predictor '{}'", pc.name))?;
        }
        let router = Router::new(config.routing.clone());
        let max_batch = config.server.max_batch;
        let max_batch_delay = Duration::from_micros(config.server.max_batch_delay_us);
        let snapshot = SnapCell::new(Arc::new(EngineSnapshot::build(
            router.snapshot(),
            &registry,
            None,
            max_batch,
            max_batch_delay,
        )));
        Ok(Engine {
            router,
            registry,
            features: FeatureStore::new(),
            lake: Arc::new(DataLake::new()),
            shadow_pool: ThreadPool::new(2.max(config.server.workers / 2)),
            snapshot,
            max_batch,
            max_batch_delay,
            live_latency: LatencyHistogram::new(),
            counters: Counters::new(),
            quantile_points,
        })
    }

    /// Whether `snap` was compiled from the current routing config
    /// and registry deployment set (pointer identity + generation —
    /// two wait-free loads, no locks).
    fn snapshot_is_fresh(&self, snap: &EngineSnapshot) -> bool {
        std::ptr::eq(Arc::as_ptr(&snap.routing), self.router.config_ptr())
            && snap.registry_generation == self.registry.generation()
    }

    /// The current data-plane snapshot, republished first if the
    /// routing config or the registry changed behind the engine's
    /// back (direct `router.swap` / `registry` callers: tests,
    /// harnesses). The fast path is one wait-free load plus two
    /// staleness comparisons.
    pub fn load_snapshot(&self) -> Arc<EngineSnapshot> {
        let snap = self.snapshot.load();
        if self.snapshot_is_fresh(&snap) {
            return snap;
        }
        self.republish()
    }

    /// Rebuild the data-plane snapshot from the current routing config
    /// and registry, publish it, and shut down batchers whose
    /// predictor was decommissioned. Control-plane rate only; the
    /// request path never calls this unless routing or registry were
    /// mutated directly. Concurrent callers serialize on the snapshot
    /// writer lock, and all but the first discover freshness under
    /// the lock and no-op instead of republishing identical worlds.
    pub fn republish(&self) -> Arc<EngineSnapshot> {
        let mut next_out: Option<Arc<EngineSnapshot>> = None;
        let removed = self.snapshot.rcu(|old| {
            if self.snapshot_is_fresh(old) {
                next_out = Some(Arc::clone(old));
                return (Arc::clone(old), Vec::new());
            }
            let next = Arc::new(EngineSnapshot::build(
                self.router.snapshot(),
                &self.registry,
                Some(old.as_ref()),
                self.max_batch,
                self.max_batch_delay,
            ));
            let removed = old.removed_entries(&next);
            next_out = Some(Arc::clone(&next));
            (next, removed)
        });
        for entry in removed {
            entry.batcher.shutdown();
        }
        next_out.expect("rcu always publishes")
    }

    /// Look up the reference distribution named in a predictor config.
    pub fn reference(name: &str) -> ReferenceDistribution {
        match name {
            "uniform" => ReferenceDistribution::uniform(),
            _ => ReferenceDistribution::fraud_default(),
        }
    }

    /// Score one event end to end (the hot path). Exactly one
    /// wait-free snapshot load; no `RwLock`, no `Mutex`, no `HashMap`
    /// probe between request and batcher.
    pub fn score(&self, req: &ScoreRequest) -> Result<ScoreResponse> {
        let t0 = Instant::now();
        let snap = self.load_snapshot();
        let resolution = Router::resolve_in(&snap.routing, &req.intent)?;
        let entry = snap.live_entry(resolution.rule_index).ok_or_else(|| {
            anyhow!("routed to undeployed predictor '{}'", resolution.live)
        })?;
        let enriched =
            self.features
                .enrich(&req.entity, &req.features, entry.predictor.feature_dim())?;
        // Hot path goes through the per-predictor dynamic batcher:
        // concurrent requests share one PJRT call; T^Q stays
        // per-tenant (applied post-aggregation inside the batcher).
        let (score, raw) = entry.batcher.score(enriched, &req.intent.tenant)?;
        self.lake
            .append(&req.intent.tenant, &entry.predictor.name, score, raw, false);

        // Mirror to shadows off the hot path.
        let shadow_count = resolution.shadows.len();
        if shadow_count > 0 {
            self.dispatch_shadows(&snap, &resolution, &req.intent.tenant, &req.entity, &req.features);
        }

        self.live_latency.record(t0.elapsed().as_nanos() as u64);
        self.counters.inc("requests_live");
        Ok(ScoreResponse {
            score,
            predictor: resolution.live.to_string(),
            shadow_count,
        })
    }

    fn dispatch_shadows(
        &self,
        snap: &EngineSnapshot,
        resolution: &Resolution,
        tenant: &str,
        entity: &str,
        payload: &[f32],
    ) {
        for shadow_name in &resolution.shadows {
            // Missing entry = the predictor is not in this snapshot's
            // deployment set (undeployed target, or torn down behind
            // the router's back — the registry-generation staleness
            // gate guarantees the snapshot tracks direct registry
            // mutations by the next request). Counted, never scored.
            let Some(entry) = snap.entry(shadow_name) else {
                self.counters.inc("shadow_missing_predictor");
                continue;
            };
            let enriched = match self
                .features
                .enrich(entity, payload, entry.predictor.feature_dim())
            {
                Ok(e) => e,
                Err(_) => {
                    self.counters.inc("shadow_enrich_error");
                    continue;
                }
            };
            // Shadows share the model containers with live traffic, so
            // they go through the same dynamic batcher — unbatched
            // shadow calls on a wide ensemble would otherwise starve
            // the live path (EXPERIMENTS.md "Perf log", step 3).
            let batcher: Arc<Batcher> = Arc::clone(&entry.batcher);
            let lake = Arc::clone(&self.lake);
            let tenant = tenant.to_string();
            let name = entry.predictor.name.clone();
            self.shadow_pool.execute(move || {
                if let Ok((score, raw)) = batcher.score(enriched, &tenant) {
                    lake.append(&tenant, &name, score, raw, true);
                }
            });
        }
    }

    /// Block until all queued shadow work has drained (tests/harness).
    pub fn drain_shadows(&self) {
        self.shadow_pool.wait_idle();
    }

    /// Batched replay of a feature matrix through a predictor
    /// (harness path: Figs. 4/6, quantile fitting, calibration).
    /// Returns (final_scores, raw_scores).
    pub fn score_matrix(
        &self,
        predictor: &str,
        features: &[f32],
        n: usize,
        tenant: &str,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let p = self
            .registry
            .get(predictor)
            .with_context(|| format!("unknown predictor '{predictor}'"))?;
        let batch = p.score(features, n, tenant)?;
        Ok((batch.scores, batch.raw))
    }

    pub fn predictor(&self, name: &str) -> Result<Arc<Predictor>> {
        self.registry
            .get(name)
            .with_context(|| format!("unknown predictor '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::PathBuf;

    const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 custom"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "p1"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "global"
  shadowRules:
  - description: "shadow p2 for bank1"
    condition:
      tenants: ["bank1"]
    targetPredictorNames: ["p2"]
predictors:
- name: p1
  experts: [m1, m2]
  quantile: identity
- name: p2
  experts: [m1, m2, m3]
  quantile: identity
- name: global
  experts: [m1]
  quantile: identity
server:
  workers: 4
"#;

    fn engine() -> Option<Engine> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let pool = Arc::new(ModelPool::new(Manifest::load(root).unwrap()));
        let cfg = MuseConfig::from_yaml(CONFIG).unwrap();
        Some(Engine::build(&cfg, pool).unwrap())
    }

    fn req(tenant: &str, d: usize, seed: u64) -> ScoreRequest {
        let mut rng = crate::util::rng::Rng::new(seed);
        ScoreRequest {
            intent: Intent {
                tenant: tenant.into(),
                ..Intent::default()
            },
            entity: format!("e{seed}"),
            features: (0..d).map(|_| rng.normal() as f32).collect(),
        }
    }

    #[test]
    fn live_and_shadow_paths() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("p1").unwrap().feature_dim();
        let r = engine.score(&req("bank1", d, 1)).unwrap();
        assert_eq!(r.predictor, "p1");
        assert_eq!(r.shadow_count, 1);
        assert!((0.0..=1.0).contains(&r.score));
        engine.drain_shadows();
        // Live record + shadow record in the lake.
        assert_eq!(engine.lake.raw_scores("bank1", "p1").len(), 1);
        assert_eq!(engine.lake.raw_scores("bank1", "p2").len(), 1);
        let counts = engine.lake.counts();
        assert_eq!(counts[&("bank1".into(), "p2".into(), true)], 1);
    }

    #[test]
    fn catch_all_tenant_has_no_shadows() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("global").unwrap().feature_dim();
        let r = engine.score(&req("newclient", d, 2)).unwrap();
        assert_eq!(r.predictor, "global");
        assert_eq!(r.shadow_count, 0);
    }

    #[test]
    fn shadow_scores_differ_from_live_but_share_input() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("p1").unwrap().feature_dim();
        for s in 0..16 {
            engine.score(&req("bank1", d, 100 + s)).unwrap();
        }
        engine.drain_shadows();
        let live = engine.lake.raw_scores("bank1", "p1");
        let shadow = engine.lake.raw_scores("bank1", "p2");
        assert_eq!(live.len(), 16);
        assert_eq!(shadow.len(), 16);
        // p2 adds m3, so raw scores differ (almost surely).
        let diffs = live
            .iter()
            .zip(&shadow)
            .filter(|(a, b)| (*a - *b).abs() > 1e-9)
            .count();
        assert!(diffs > 0, "shadow identical to live");
    }

    #[test]
    fn partial_payload_is_enriched() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("global").unwrap().feature_dim();
        engine.features.put("card-7", vec![0.5; d]);
        let mut r = req("x", d / 2, 3); // half payload
        r.entity = "card-7".into();
        let resp = engine.score(&r).unwrap();
        assert!((0.0..=1.0).contains(&resp.score));
    }

    #[test]
    fn latency_is_recorded() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("global").unwrap().feature_dim();
        for s in 0..8 {
            engine.score(&req("t", d, 200 + s)).unwrap();
        }
        assert_eq!(engine.live_latency.count(), 8);
        assert!(engine.live_latency.percentile_ns(50.0) > 0);
        assert_eq!(engine.counters.get("requests_live"), 8);
    }

    #[test]
    fn score_matrix_batches() {
        let Some(engine) = engine() else { return };
        let p = engine.predictor("p1").unwrap();
        let d = p.feature_dim();
        let mut rng = crate::util::rng::Rng::new(4);
        let n = 100;
        let feats: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let (scores, raw) = engine.score_matrix("p1", &feats, n, "t").unwrap();
        assert_eq!(scores.len(), n);
        assert_eq!(raw.len(), n);
        // Identity T^Q: final == raw.
        for (s, r) in scores.iter().zip(&raw) {
            assert!((s - r).abs() < 1e-9);
        }
    }

    #[test]
    fn unknown_tenant_routes_to_catch_all_not_error() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("global").unwrap().feature_dim();
        assert!(engine.score(&req("anyone", d, 5)).is_ok());
    }

    #[test]
    fn direct_router_swap_is_picked_up_lazily() {
        // Harnesses swap the router without going through the control
        // plane; the engine's staleness check must republish and serve
        // the new routing on the very next request.
        let Some(engine) = engine() else { return };
        let d = engine.predictor("global").unwrap().feature_dim();
        assert_eq!(engine.score(&req("bank1", d, 6)).unwrap().predictor, "p1");
        let mut cfg = engine.router.snapshot().as_ref().clone();
        cfg.scoring_rules[0].target_predictor = "p2".into();
        engine.router.swap(cfg);
        assert_eq!(engine.score(&req("bank1", d, 7)).unwrap().predictor, "p2");
    }

    #[test]
    fn snapshot_reuses_batchers_across_republish() {
        let Some(engine) = engine() else { return };
        let before = engine.load_snapshot();
        let b_before = Arc::as_ptr(&before.entry("p1").unwrap().batcher);
        engine.router.swap(engine.router.snapshot().as_ref().clone());
        let after = engine.load_snapshot();
        assert_eq!(
            b_before,
            Arc::as_ptr(&after.entry("p1").unwrap().batcher),
            "republish must not restart live batchers"
        );
    }
}
