//! The serving engine: the stateless orchestration layer of Fig. 1.
//!
//! One `score()` call runs the full request path: intent routing ->
//! feature-store enrichment -> predictor DAG (expert inference on the
//! shared containers, `T^C`, `A`, tenant-specific `T^Q`) -> response,
//! while mirroring the request to every matching shadow predictor
//! asynchronously (shadow latency never blocks the live response) and
//! recording scores to the data lake.
//!
//! The live path is lock-free: one wait-free [`EngineSnapshot`] load
//! per request, then an index-based hop to the resolved predictor +
//! batcher (see `coordinator::snapshot` for the publication
//! protocol, and EXPERIMENTS.md "Contention" for the measured win
//! over the seed's two-`RwLock` path).

use super::batcher::Batcher;
use super::predictor::Predictor;
use super::registry::PredictorRegistry;
use super::router::{Resolution, Router};
use super::snapshot::EngineSnapshot;
use super::tenants::{TenantHandle, TenantInterner};
use crate::config::{Intent, MuseConfig, QuantileMode};
use crate::datalake::DataLake;
use crate::featurestore::FeatureStore;
use crate::lifecycle::LifecycleHub;
use crate::metrics::{CounterHandle, Counters, LatencyHistogram, TenantCounters};
use crate::runtime::ModelPool;
use crate::transforms::{PipelineScratch, QuantileMap, ReferenceDistribution};
use crate::util::swap::SnapCell;
use crate::util::threadpool::ThreadPool;
use anyhow::{anyhow, ensure, Context, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One scoring request (the client payload).
#[derive(Debug, Clone)]
pub struct ScoreRequest {
    pub intent: Intent,
    /// Entity key for feature-store enrichment (e.g. card hash).
    pub entity: String,
    /// Payload features; enriched up to the model dim if partial.
    pub features: Vec<f32>,
}

/// The client-visible response. The predictor name is a shared
/// `Arc<str>` clone of the routing config's own string — a refcount
/// bump, not a per-event `String` allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct ScoreResponse {
    pub score: f64,
    pub predictor: Arc<str>,
    /// Number of shadow predictors the request was mirrored to.
    pub shadow_count: usize,
}

/// Pre-resolved handles for every counter the per-event paths bump:
/// resolved once at engine build into direct atomics, so the hot path
/// performs zero map probes and zero lock acquisitions for metrics
/// (`metrics::counters` module docs). The same counters stay visible
/// under their names in `/metrics` — handles alias the registry's own
/// atomics.
pub struct HotCounters {
    pub requests_live: CounterHandle,
    pub requests_batch: CounterHandle,
    pub events_batch: CounterHandle,
    pub shadow_missing_predictor: CounterHandle,
    pub shadow_enrich_error: CounterHandle,
}

impl HotCounters {
    fn resolve(counters: &Counters) -> HotCounters {
        HotCounters {
            requests_live: counters.handle("requests_live"),
            requests_batch: counters.handle("requests_batch"),
            events_batch: counters.handle("events_batch"),
            shadow_missing_predictor: counters.handle("shadow_missing_predictor"),
            shadow_enrich_error: counters.handle("shadow_enrich_error"),
        }
    }
}

/// Counter names the lifecycle controller bumps at tick rate,
/// pre-interned at build so even a first drift event never pays the
/// registry's copy-on-write insert on a serving box.
const LIFECYCLE_COUNTER_NAMES: &[&str] = &[
    "lifecycle_ticks",
    "lifecycle_fits",
    "lifecycle_drift_detected",
    "lifecycle_promotions",
    "lifecycle_validation_failures",
    "lifecycle_shadow_timeouts",
    "lifecycle_decommissions",
    "lifecycle_decommission_races",
    "lifecycle_samples_dropped",
    "lifecycle_errors",
    "lifecycle_feed_evictions",
    "lifecycle_feed_repromotions",
    "lifecycle_cold_missed_samples",
];

pub struct Engine {
    pub router: Router,
    pub registry: PredictorRegistry,
    pub features: FeatureStore,
    pub lake: Arc<DataLake>,
    shadow_pool: ThreadPool,
    /// The compiled data-plane snapshot: routing + resolved predictor
    /// handles + per-predictor dynamic batchers, swapped atomically by
    /// the control plane. Batchers matter because concurrent
    /// single-event requests coalesce into one PJRT call — batch-256
    /// inference is ~80x cheaper per event than batch-1
    /// (EXPERIMENTS.md "Perf log", step 1).
    snapshot: SnapCell<EngineSnapshot>,
    max_batch: usize,
    max_batch_delay: Duration,
    /// Admission cap for one `score_batch` call (config
    /// `server.maxBatchEvents`). Enforced here, in the engine; the
    /// HTTP layer only surfaces the resulting error as a 422.
    pub max_batch_events: usize,
    /// HTTP request-body cap (config `server.maxBodyBytes`), consumed
    /// by the HTTP front end when it binds.
    pub max_body_bytes: usize,
    /// The full `server:` config block, kept on the engine so the
    /// ingress plane can wire limits, read deadlines and
    /// tenant-priority admission without re-reading config files.
    pub server_cfg: crate::config::ServerConfig,
    pub live_latency: LatencyHistogram,
    /// Whole-batch wall time per `score_batch` call — kept separate
    /// from `live_latency` so batch totals never pollute the
    /// single-request percentiles `/metrics` reports.
    pub batch_latency: LatencyHistogram,
    pub counters: Counters,
    /// Pre-resolved per-event counter handles (see [`HotCounters`]).
    pub hot: HotCounters,
    /// Batch-path scored events per tenant, on a handle-indexed slab
    /// sharded like the interner (surfaced as the `scored_events`
    /// object in `/metrics` through [`Engine::scored_events_for_each`]
    /// — names re-attach at read time via the interner). Updated once
    /// per (batch, tenant) group — the single-event hot path is
    /// untouched, and a bump is a direct atomic with no name hashing.
    pub tenant_events: TenantCounters,
    /// Quantile grid resolution (from the manifest).
    pub quantile_points: usize,
    /// Lifecycle autopilot hub (`lifecycle.enabled`): the hot paths
    /// feed raw scores into its lock-free per-worker rings (one
    /// wait-free table load + one atomic append per event); draining,
    /// drift scoring and the shadow→promote loop run off-path in
    /// [`LifecycleHub::tick`].
    pub lifecycle: Option<Arc<LifecycleHub>>,
    /// The engine-wide tenant interner: requests resolve their tenant
    /// name to a dense [`TenantHandle`] once, at the ingress edge, and
    /// every downstream tenant-keyed structure (batcher submissions,
    /// quantile pipelines, lake pair slots, event counters, lifecycle
    /// feeds, admission priorities) indexes by that handle. Shared
    /// with the registry (predictor quantile tables) and the server's
    /// admission controller.
    pub tenants: Arc<TenantInterner>,
}

impl Engine {
    /// Build the engine from a validated config and a model pool.
    /// Predictors with `quantile: default` get the cold-start
    /// transformation installed by the control plane afterwards
    /// (`ControlPlane::fit_default_quantile`); they start at identity.
    pub fn build(config: &MuseConfig, pool: Arc<ModelPool>) -> Result<Engine> {
        config.validate()?;
        let quantile_points = pool.manifest().quantile_points;
        let tenants = Arc::new(TenantInterner::with_shards(config.server.tenant_shards));
        let registry = PredictorRegistry::with_interner(pool, Arc::clone(&tenants));
        for pc in &config.predictors {
            let initial: Arc<QuantileMap> = match pc.quantile_mode {
                QuantileMode::Identity | QuantileMode::Custom | QuantileMode::Default => {
                    QuantileMap::identity(quantile_points.max(2))?.shared()
                }
            };
            registry
                .deploy(pc, initial)
                .with_context(|| format!("deploy predictor '{}'", pc.name))?;
        }
        let router = Router::new(config.routing.clone());
        let max_batch = config.server.max_batch;
        let max_batch_delay = Duration::from_micros(config.server.max_batch_delay_us);
        let snapshot = SnapCell::new(Arc::new(EngineSnapshot::build(
            router.snapshot(),
            &registry,
            None,
            max_batch,
            max_batch_delay,
        )));
        let lifecycle = config.lifecycle.enabled.then(|| {
            Arc::new(LifecycleHub::new(
                config.lifecycle.clone(),
                Arc::clone(&tenants),
            ))
        });
        let counters = Counters::new();
        let hot = HotCounters::resolve(&counters);
        for name in LIFECYCLE_COUNTER_NAMES {
            let _ = counters.handle(name);
        }
        Ok(Engine {
            router,
            registry,
            features: FeatureStore::new(),
            lake: Arc::new(DataLake::with_shards(
                config.server.lake_max_records,
                config.server.lake_shards,
            )),
            shadow_pool: ThreadPool::new(2.max(config.server.workers / 2)),
            snapshot,
            max_batch,
            max_batch_delay,
            max_batch_events: config.server.max_batch_events,
            max_body_bytes: config.server.max_body_bytes,
            server_cfg: config.server.clone(),
            live_latency: LatencyHistogram::new(),
            batch_latency: LatencyHistogram::new(),
            counters,
            hot,
            tenant_events: TenantCounters::new(config.server.tenant_shards),
            quantile_points,
            lifecycle,
            tenants,
        })
    }

    /// Whether `snap` was compiled from the current routing config
    /// and registry deployment set (pointer identity + generation —
    /// two wait-free loads, no locks).
    fn snapshot_is_fresh(&self, snap: &EngineSnapshot) -> bool {
        std::ptr::eq(Arc::as_ptr(&snap.routing), self.router.config_ptr())
            && snap.registry_generation == self.registry.generation()
    }

    /// The current data-plane snapshot, republished first if the
    /// routing config or the registry changed behind the engine's
    /// back (direct `router.swap` / `registry` callers: tests,
    /// harnesses). The fast path is one wait-free load plus two
    /// staleness comparisons.
    pub fn load_snapshot(&self) -> Arc<EngineSnapshot> {
        let snap = self.snapshot.load();
        if self.snapshot_is_fresh(&snap) {
            return snap;
        }
        self.republish()
    }

    /// Ingress-admission pressure signal: the deepest dynamic-batcher
    /// queue across deployed predictors right now. Wait-free (one
    /// snapshot load plus relaxed gauge reads) so the ingress plane can
    /// probe it on every `/v1/score/batch` request without touching
    /// the data path.
    pub fn ingress_pressure(&self) -> usize {
        self.load_snapshot().max_batcher_depth()
    }

    /// Batch-path scored events for one tenant name (observability /
    /// verification surface). Retired-and-reonboarded tenants hold
    /// several handles over their lifetime; this sums every slot whose
    /// handle currently resolves from — or ever resolved to — the
    /// name, matching the one-key-per-name view `/metrics` serves.
    pub fn scored_events(&self, tenant: &str) -> u64 {
        let mut total = 0;
        self.scored_events_for_each(|name, n| {
            if name == tenant {
                total += n;
            }
        });
        total
    }

    /// Stream every non-zero per-tenant scored-event counter as
    /// `(name, count)`, in slab (handle-allocation) order. The same
    /// name may be visited more than once (a tenant retired and
    /// re-onboarded owns several handles) — aggregating consumers sum,
    /// which is what [`Engine::scored_events_snapshot`] and the
    /// `/metrics` writer do. Zero-count slots (routes interned by
    /// non-counting paths) are skipped: the observable map contains
    /// exactly the tenants the batch path accounted.
    pub fn scored_events_for_each(&self, mut f: impl FnMut(&str, u64)) {
        self.tenant_events.for_each(|index, n| {
            if n == 0 {
                return;
            }
            if let Some(name) = self.tenants.name(TenantHandle::from_index(index)) {
                f(&name, n);
            }
        });
    }

    /// Materialized per-tenant scored-event counts by name (sorted;
    /// duplicate handles for one name summed). Verification-plane
    /// convenience — `/metrics` streams via
    /// [`Engine::scored_events_for_each`] instead of cloning this map.
    pub fn scored_events_snapshot(&self) -> std::collections::BTreeMap<String, u64> {
        let mut out = std::collections::BTreeMap::new();
        self.scored_events_for_each(|name, n| {
            *out.entry(name.to_string()).or_insert(0) += n;
        });
        out
    }

    /// Rebuild the data-plane snapshot from the current routing config
    /// and registry, publish it, and shut down batchers whose
    /// predictor was decommissioned. Control-plane rate only; the
    /// request path never calls this unless routing or registry were
    /// mutated directly. Concurrent callers serialize on the snapshot
    /// writer lock, and all but the first discover freshness under
    /// the lock and no-op instead of republishing identical worlds.
    pub fn republish(&self) -> Arc<EngineSnapshot> {
        let mut next_out: Option<Arc<EngineSnapshot>> = None;
        let removed = self.snapshot.rcu(|old| {
            if self.snapshot_is_fresh(old) {
                next_out = Some(Arc::clone(old));
                return (Arc::clone(old), Vec::new());
            }
            let next = Arc::new(EngineSnapshot::build(
                self.router.snapshot(),
                &self.registry,
                Some(old.as_ref()),
                self.max_batch,
                self.max_batch_delay,
            ));
            let removed = old.removed_entries(&next);
            next_out = Some(Arc::clone(&next));
            (next, removed)
        });
        for entry in removed {
            entry.batcher.shutdown();
        }
        next_out.expect("rcu always publishes")
    }

    /// Look up the reference distribution named in a predictor config.
    pub fn reference(name: &str) -> ReferenceDistribution {
        match name {
            "uniform" => ReferenceDistribution::uniform(),
            _ => ReferenceDistribution::fraud_default(),
        }
    }

    /// Score one event end to end (the hot path). Exactly one
    /// wait-free snapshot load; **zero** `RwLock`/`Mutex` acquisitions
    /// anywhere on the path — routing, enrichment, batcher submit,
    /// lake append, lifecycle feed, latency record and counters are
    /// all wait-free — and zero heap allocations outside enrichment
    /// and inference (the batcher borrows the enriched features). The
    /// tenant name is hashed exactly once, at the interner below;
    /// everything after that point — batcher submit, quantile
    /// pipeline, lake pair slot, lifecycle feed — indexes by the dense
    /// [`TenantHandle`] through the entry's cached [`TenantRoute`].
    pub fn score(&self, req: &ScoreRequest) -> Result<ScoreResponse> {
        let t0 = Instant::now();
        let snap = self.load_snapshot();
        let resolution = Router::resolve_in(&snap.routing, &req.intent)?;
        let entry = snap.live_entry(resolution.rule_index).ok_or_else(|| {
            anyhow!("routed to undeployed predictor '{}'", resolution.live)
        })?;
        // The ingress edge: the request's one tenant-string hash.
        let tenant = self.tenants.resolve(&req.intent.tenant);
        let enriched =
            self.features
                .enrich(&req.entity, &req.features, entry.predictor.feature_dim())?;
        // Hot path goes through the per-predictor dynamic batcher:
        // concurrent requests share one PJRT call; T^Q stays
        // per-tenant (applied post-aggregation inside the batcher).
        // The submit borrows features and carries the Copy handle — no
        // reply channel, no clone (coordinator::batcher module docs).
        let (score, raw) = entry.batcher.score(&enriched, tenant)?;
        // Commit side effects through the cached per-(predictor,
        // tenant) route: lake append and lifecycle feed are direct
        // slot/ring operations, no string re-hashing.
        let route = entry.route(
            tenant,
            &req.intent.tenant,
            &self.lake,
            self.lifecycle.as_deref(),
        );
        self.lake.append_ref(&route.pair, score, raw, false);
        if let Some(feed) = &route.feed {
            feed.push(raw);
        }

        // Mirror to shadows off the hot path.
        let shadow_count = resolution.shadows.len();
        if shadow_count > 0 {
            self.dispatch_shadows(
                &snap,
                &resolution,
                tenant,
                &req.intent.tenant,
                &req.entity,
                &req.features,
            );
        }

        self.live_latency.record(t0.elapsed().as_nanos() as u64);
        self.hot.requests_live.inc();
        Ok(ScoreResponse {
            score,
            predictor: resolution.live,
            shadow_count,
        })
    }

    /// Score a whole batch end to end off **one** wait-free snapshot
    /// load. Requests are grouped by intent; each group is routed
    /// once, enriched, and scored through the predictor's **compiled
    /// pipeline** (`transforms::pipeline`) — expert inference is one
    /// batched fan-out per group and the tenant's `T^Q` is resolved
    /// with a single probe per group, so the live path performs zero
    /// per-event tenant hashmap lookups. Shadows are mirrored once per
    /// group (the whole sub-batch, off the hot path). Responses come
    /// back in input order; any per-event failure fails the call (the
    /// batch is one unit of work, mirroring HTTP semantics), and side
    /// effects — data-lake records, per-tenant counters, shadow
    /// mirrors — are committed only after **every** group has scored,
    /// so a failed batch leaves no partial state behind.
    pub fn score_batch(&self, reqs: &[ScoreRequest]) -> Result<Vec<ScoreResponse>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        ensure!(
            reqs.len() <= self.max_batch_events,
            "batch of {} events exceeds maxBatchEvents = {}",
            reqs.len(),
            self.max_batch_events
        );
        let t0 = Instant::now();
        let snap = self.load_snapshot();

        // Route once per distinct intent (linear scan: batches carry a
        // handful of intents, typically one per tenant).
        struct Group {
            first: usize,
            indices: Vec<usize>,
            resolution: Resolution,
        }
        let mut groups: Vec<Group> = Vec::new();
        for (i, req) in reqs.iter().enumerate() {
            match groups
                .iter()
                .position(|g| reqs[g.first].intent == req.intent)
            {
                Some(gi) => groups[gi].indices.push(i),
                None => groups.push(Group {
                    first: i,
                    indices: vec![i],
                    resolution: Router::resolve_in(&snap.routing, &req.intent)?,
                }),
            }
        }

        // Phase 1 — score every group, no side effects. A failure in
        // any group (enrichment, inference) aborts the whole call
        // *before* anything is recorded, so a client retry of a failed
        // batch never double-records events in the data lake or the
        // per-tenant counters. The enriched matrix is kept per group
        // so shadow mirroring can reuse it instead of re-enriching.
        struct Scored {
            scores: Vec<f64>,
            raw: Vec<f64>,
            matrix: Vec<f32>,
            dim: usize,
            tenant: TenantHandle,
        }
        let mut scratch = PipelineScratch::default();
        let mut results: Vec<Scored> = Vec::with_capacity(groups.len());
        for g in &groups {
            let entry = snap.live_entry(g.resolution.rule_index).ok_or_else(|| {
                anyhow!("routed to undeployed predictor '{}'", g.resolution.live)
            })?;
            let d = entry.predictor.feature_dim();
            let n = g.indices.len();
            // One tenant-string hash per (batch, tenant) group; the
            // pipeline probe below and every phase-2 side effect index
            // by the handle.
            let tenant = self.tenants.resolve(&reqs[g.first].intent.tenant);
            let mut matrix: Vec<f32> = Vec::with_capacity(n * d);
            for &i in &g.indices {
                let enriched = self
                    .features
                    .enrich(&reqs[i].entity, &reqs[i].features, d)?;
                matrix.extend_from_slice(&enriched);
            }
            let (mut raw, mut scores) = (Vec::new(), Vec::new());
            entry.predictor.score_batch_for_tenant_handle(
                &matrix,
                n,
                tenant,
                &mut scratch,
                &mut raw,
                &mut scores,
            )?;
            results.push(Scored {
                scores,
                raw,
                matrix,
                dim: d,
                tenant,
            });
        }

        // Phase 2 — every group scored: commit side effects and build
        // the responses.
        let mut out: Vec<Option<ScoreResponse>> = (0..reqs.len()).map(|_| None).collect();
        for (g, scored) in groups.iter().zip(&results) {
            let entry = snap
                .live_entry(g.resolution.rule_index)
                .expect("resolved in phase 1 against the same snapshot");
            let n = g.indices.len();
            let tenant_name = &reqs[g.first].intent.tenant;
            // One cached route per (batch, tenant) group: the lake
            // append, the per-tenant counter and the lifecycle feed
            // are slot/atomic/ring operations off the handle.
            let route = entry.route(
                scored.tenant,
                tenant_name,
                &self.lake,
                self.lifecycle.as_deref(),
            );
            self.lake
                .append_batch_ref(&route.pair, &scored.scores, &scored.raw, false);
            route.counter(&self.tenant_events).add(n as u64);
            if let Some(feed) = &route.feed {
                for &r in &scored.raw {
                    feed.push(r);
                }
            }

            let shadow_count = g.resolution.shadows.len();
            if shadow_count > 0 {
                self.dispatch_shadow_batch(
                    &snap,
                    &g.resolution,
                    &g.indices,
                    reqs,
                    scored.tenant,
                    tenant_name,
                    &scored.matrix,
                    scored.dim,
                );
            }
            for (slot, &i) in g.indices.iter().enumerate() {
                out[i] = Some(ScoreResponse {
                    score: scored.scores[slot],
                    predictor: Arc::clone(&g.resolution.live),
                    shadow_count,
                });
            }
        }
        self.batch_latency.record(t0.elapsed().as_nanos() as u64);
        self.hot.requests_batch.inc();
        self.hot.events_batch.add(reqs.len() as u64);
        Ok(out
            .into_iter()
            .map(|r| r.expect("every request belongs to exactly one group"))
            .collect())
    }

    fn dispatch_shadows(
        &self,
        snap: &EngineSnapshot,
        resolution: &Resolution,
        tenant: TenantHandle,
        tenant_name: &str,
        entity: &str,
        payload: &[f32],
    ) {
        for shadow_name in &resolution.shadows {
            // Missing entry = the predictor is not in this snapshot's
            // deployment set (undeployed target, or torn down behind
            // the router's back — the registry-generation staleness
            // gate guarantees the snapshot tracks direct registry
            // mutations by the next request). Counted, never scored.
            let Some(entry) = snap.entry(shadow_name) else {
                self.hot.shadow_missing_predictor.inc();
                continue;
            };
            let enriched = match self
                .features
                .enrich(entity, payload, entry.predictor.feature_dim())
            {
                Ok(e) => e,
                Err(_) => {
                    self.hot.shadow_enrich_error.inc();
                    continue;
                }
            };
            // Shadows share the model containers with live traffic, so
            // they go through the same dynamic batcher — unbatched
            // shadow calls on a wide ensemble would otherwise starve
            // the live path (EXPERIMENTS.md "Perf log", step 3).
            // The closure captures the Copy handle and the shadow
            // entry's cached route — no tenant `String` clone, no
            // predictor-name clone, no string hashing on the pool
            // thread.
            let batcher: Arc<Batcher> = Arc::clone(&entry.batcher);
            let lake = Arc::clone(&self.lake);
            let route = entry.route(tenant, tenant_name, &self.lake, self.lifecycle.as_deref());
            self.shadow_pool.execute(move || {
                if let Ok((score, raw)) = batcher.score(&enriched, tenant) {
                    lake.append_ref(&route.pair, score, raw, true);
                }
            });
        }
    }

    /// Mirror one routed batch group to every matching shadow
    /// predictor. Inference + transforms run on the shadow pool
    /// through the shadow predictor's compiled pipeline; only
    /// enrichment can touch the caller thread (the feature store is
    /// not shareable into the pool), and when the shadow's feature
    /// dim matches the live predictor's — the common case — the
    /// already-enriched live matrix is copied instead of re-enriching
    /// every event. Unlike the single-event path, batch shadows bypass
    /// the dynamic batcher: the group already *is* a batch, so
    /// re-queueing it event-by-event would only add latency.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_shadow_batch(
        &self,
        snap: &EngineSnapshot,
        resolution: &Resolution,
        indices: &[usize],
        reqs: &[ScoreRequest],
        tenant: TenantHandle,
        tenant_name: &str,
        live_matrix: &[f32],
        live_dim: usize,
    ) {
        let n = indices.len();
        for shadow_name in &resolution.shadows {
            let Some(entry) = snap.entry(shadow_name) else {
                self.hot.shadow_missing_predictor.inc();
                continue;
            };
            let d = entry.predictor.feature_dim();
            let matrix: Vec<f32> = if d == live_dim {
                live_matrix.to_vec()
            } else {
                let mut m: Vec<f32> = Vec::with_capacity(n * d);
                let mut ok = true;
                for &i in indices {
                    match self.features.enrich(&reqs[i].entity, &reqs[i].features, d) {
                        Ok(e) => m.extend_from_slice(&e),
                        Err(_) => {
                            self.hot.shadow_enrich_error.inc();
                            ok = false;
                            break;
                        }
                    }
                }
                if !ok {
                    continue;
                }
                m
            };
            // Copy handle + cached route into the closure — no tenant
            // `String` clone crosses to the pool thread.
            let predictor = Arc::clone(&entry.predictor);
            let lake = Arc::clone(&self.lake);
            let route = entry.route(tenant, tenant_name, &self.lake, self.lifecycle.as_deref());
            self.shadow_pool.execute(move || {
                let mut scratch = PipelineScratch::default();
                let (mut raw, mut scores) = (Vec::new(), Vec::new());
                let ok = predictor
                    .score_batch_for_tenant_handle(
                        &matrix,
                        n,
                        tenant,
                        &mut scratch,
                        &mut raw,
                        &mut scores,
                    )
                    .is_ok();
                if ok {
                    lake.append_batch_ref(&route.pair, &scores, &raw, true);
                }
            });
        }
    }

    /// Block until all queued shadow work has drained (tests/harness).
    pub fn drain_shadows(&self) {
        self.shadow_pool.wait_idle();
    }

    /// Batched replay of a feature matrix through a predictor
    /// (harness path: Figs. 4/6, quantile fitting, calibration).
    /// Returns (final_scores, raw_scores).
    pub fn score_matrix(
        &self,
        predictor: &str,
        features: &[f32],
        n: usize,
        tenant: &str,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let p = self
            .registry
            .get(predictor)
            .with_context(|| format!("unknown predictor '{predictor}'"))?;
        let batch = p.score(features, n, tenant)?;
        Ok((batch.scores, batch.raw))
    }

    pub fn predictor(&self, name: &str) -> Result<Arc<Predictor>> {
        self.registry
            .get(name)
            .with_context(|| format!("unknown predictor '{name}'"))
    }
}

/// Verification-plane introspection (`testkit`): the oracle-diff
/// harness (`testkit::harness`) compares the engine's published world
/// against a sequential oracle after every generated command storm.
/// These read-only hooks expose state that is deliberately private in
/// production — compiled into the binary only under `cfg(test)` or the
/// `testkit` feature, so they cannot rot unnoticed (CI builds
/// `--features testkit`).
#[cfg(any(test, feature = "testkit"))]
impl Engine {
    /// Sorted predictor names in the current data-plane snapshot
    /// (republishing first if routing/registry changed behind it).
    pub fn snapshot_predictor_names(&self) -> Vec<String> {
        self.load_snapshot().entry_names()
    }

    /// Per-predictor dynamic-batcher totals from the current snapshot
    /// — the harness's event-conservation source.
    pub fn batcher_event_totals(&self) -> Vec<(String, super::batcher::BatcherStats)> {
        self.load_snapshot().batcher_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::PathBuf;

    const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 custom"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "p1"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "global"
  shadowRules:
  - description: "shadow p2 for bank1"
    condition:
      tenants: ["bank1"]
    targetPredictorNames: ["p2"]
predictors:
- name: p1
  experts: [m1, m2]
  quantile: identity
- name: p2
  experts: [m1, m2, m3]
  quantile: identity
- name: global
  experts: [m1]
  quantile: identity
server:
  workers: 4
  maxBatchEvents: 64
"#;

    fn engine() -> Option<Engine> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let pool = Arc::new(ModelPool::new(Manifest::load(root).unwrap()));
        let cfg = MuseConfig::from_yaml(CONFIG).unwrap();
        Some(Engine::build(&cfg, pool).unwrap())
    }

    fn req(tenant: &str, d: usize, seed: u64) -> ScoreRequest {
        let mut rng = crate::util::rng::Rng::new(seed);
        ScoreRequest {
            intent: Intent {
                tenant: tenant.into(),
                ..Intent::default()
            },
            entity: format!("e{seed}"),
            features: (0..d).map(|_| rng.normal() as f32).collect(),
        }
    }

    #[test]
    fn live_and_shadow_paths() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("p1").unwrap().feature_dim();
        let r = engine.score(&req("bank1", d, 1)).unwrap();
        assert_eq!(&*r.predictor, "p1");
        assert_eq!(r.shadow_count, 1);
        assert!((0.0..=1.0).contains(&r.score));
        engine.drain_shadows();
        // Live record + shadow record in the lake.
        assert_eq!(engine.lake.raw_scores("bank1", "p1").len(), 1);
        assert_eq!(engine.lake.raw_scores("bank1", "p2").len(), 1);
        let counts = engine.lake.counts();
        assert_eq!(counts[&("bank1".into(), "p2".into(), true)], 1);
    }

    #[test]
    fn catch_all_tenant_has_no_shadows() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("global").unwrap().feature_dim();
        let r = engine.score(&req("newclient", d, 2)).unwrap();
        assert_eq!(&*r.predictor, "global");
        assert_eq!(r.shadow_count, 0);
    }

    #[test]
    fn shadow_scores_differ_from_live_but_share_input() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("p1").unwrap().feature_dim();
        for s in 0..16 {
            engine.score(&req("bank1", d, 100 + s)).unwrap();
        }
        engine.drain_shadows();
        let live = engine.lake.raw_scores("bank1", "p1");
        let shadow = engine.lake.raw_scores("bank1", "p2");
        assert_eq!(live.len(), 16);
        assert_eq!(shadow.len(), 16);
        // p2 adds m3, so raw scores differ (almost surely).
        let diffs = live
            .iter()
            .zip(&shadow)
            .filter(|(a, b)| (*a - *b).abs() > 1e-9)
            .count();
        assert!(diffs > 0, "shadow identical to live");
    }

    #[test]
    fn partial_payload_is_enriched() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("global").unwrap().feature_dim();
        engine.features.put("card-7", vec![0.5; d]);
        let mut r = req("x", d / 2, 3); // half payload
        r.entity = "card-7".into();
        let resp = engine.score(&r).unwrap();
        assert!((0.0..=1.0).contains(&resp.score));
    }

    #[test]
    fn latency_is_recorded() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("global").unwrap().feature_dim();
        for s in 0..8 {
            engine.score(&req("t", d, 200 + s)).unwrap();
        }
        assert_eq!(engine.live_latency.count(), 8);
        assert!(engine.live_latency.percentile_ns(50.0) > 0);
        assert_eq!(engine.counters.get("requests_live"), 8);
    }

    #[test]
    fn score_matrix_batches() {
        let Some(engine) = engine() else { return };
        let p = engine.predictor("p1").unwrap();
        let d = p.feature_dim();
        let mut rng = crate::util::rng::Rng::new(4);
        let n = 100;
        let feats: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let (scores, raw) = engine.score_matrix("p1", &feats, n, "t").unwrap();
        assert_eq!(scores.len(), n);
        assert_eq!(raw.len(), n);
        // Identity T^Q: final == raw.
        for (s, r) in scores.iter().zip(&raw) {
            assert!((s - r).abs() < 1e-9);
        }
    }

    #[test]
    fn batch_scoring_matches_sequential_scoring() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("p1").unwrap().feature_dim();
        // Mixed-intent batch: bank1 (dedicated rule + shadow) and an
        // unknown tenant (catch-all, no shadows).
        let reqs: Vec<ScoreRequest> = (0..12)
            .map(|s| {
                let tenant = if s % 3 == 0 { "bank1" } else { "other" };
                req(tenant, d, 300 + s as u64)
            })
            .collect();
        let batch = engine.score_batch(&reqs).unwrap();
        engine.drain_shadows();
        assert_eq!(batch.len(), 12);
        for (r, resp) in reqs.iter().zip(&batch) {
            let single = engine.score(r).unwrap();
            assert_eq!(single.predictor, resp.predictor);
            assert_eq!(single.shadow_count, resp.shadow_count);
            // Tolerance matches the container-level cross-batch-variant
            // bound (runtime/container.rs): the transform pipeline is
            // equivalent to 1e-12, but PJRT may execute the group under
            // a different AOT batch variant than the singles.
            assert!(
                (single.score - resp.score).abs() < 2e-5,
                "batch {} vs sequential {} ({})",
                resp.score,
                single.score,
                r.intent.tenant
            );
        }
        engine.drain_shadows();
        assert_eq!(engine.counters.get("requests_batch"), 1);
        assert_eq!(engine.counters.get("events_batch"), 12);
        // Per-tenant accounting covers the batch path (bare tenant
        // keys; the single-event hot path is deliberately untouched).
        assert_eq!(engine.scored_events("bank1"), 4);
        assert_eq!(engine.scored_events("other"), 8);
        // Batch latency is recorded separately from request latency.
        assert_eq!(engine.batch_latency.count(), 1);
        // bank1's shadow (p2) mirrored the whole sub-batch once per path.
        assert_eq!(engine.lake.raw_scores("bank1", "p2").len(), 8);
    }

    #[test]
    fn single_event_path_interns_no_tenant_event_keys() {
        // Route building is shared by the single-event, batch and
        // shadow paths, but only the batch path counts scored_events —
        // a single-event score must not leave a zero-count key behind
        // (the verification harness checks full-map equality of
        // `tenant_events` against the oracle).
        let Some(engine) = engine() else { return };
        let d = engine.predictor("p1").unwrap().feature_dim();
        engine.score(&req("bank1", d, 77)).unwrap();
        engine.drain_shadows();
        assert!(
            engine.scored_events_snapshot().is_empty(),
            "single-event path leaked scored_events keys: {:?}",
            engine.scored_events_snapshot()
        );
        // The route itself is cached: a second resolution for the same
        // tenant returns the same Arc (warm path, no rebuild).
        let snap = engine.load_snapshot();
        let entry = snap.entry("p1").unwrap();
        let h = engine.tenants.resolve("bank1");
        let a = entry.route(h, "bank1", &engine.lake, engine.lifecycle.as_deref());
        let b = entry.route(h, "bank1", &engine.lake, engine.lifecycle.as_deref());
        assert!(Arc::ptr_eq(&a, &b), "warm route must be reused, not rebuilt");
    }

    #[test]
    fn batch_respects_admission_cap_and_empty_batches() {
        let Some(engine) = engine() else { return };
        assert!(engine.score_batch(&[]).unwrap().is_empty());
        let d = engine.predictor("global").unwrap().feature_dim();
        let reqs: Vec<ScoreRequest> = (0..65).map(|s| req("t", d, 900 + s)).collect();
        let err = engine.score_batch(&reqs).unwrap_err();
        assert!(err.to_string().contains("maxBatchEvents"), "{err}");
    }

    #[test]
    fn unknown_tenant_routes_to_catch_all_not_error() {
        let Some(engine) = engine() else { return };
        let d = engine.predictor("global").unwrap().feature_dim();
        assert!(engine.score(&req("anyone", d, 5)).is_ok());
    }

    #[test]
    fn direct_router_swap_is_picked_up_lazily() {
        // Harnesses swap the router without going through the control
        // plane; the engine's staleness check must republish and serve
        // the new routing on the very next request.
        let Some(engine) = engine() else { return };
        let d = engine.predictor("global").unwrap().feature_dim();
        assert_eq!(&*engine.score(&req("bank1", d, 6)).unwrap().predictor, "p1");
        let mut cfg = engine.router.snapshot().as_ref().clone();
        cfg.scoring_rules[0].target_predictor = "p2".into();
        engine.router.swap(cfg);
        assert_eq!(&*engine.score(&req("bank1", d, 7)).unwrap().predictor, "p2");
    }

    #[test]
    fn snapshot_reuses_batchers_across_republish() {
        let Some(engine) = engine() else { return };
        let before = engine.load_snapshot();
        let b_before = Arc::as_ptr(&before.entry("p1").unwrap().batcher);
        engine.router.swap(engine.router.snapshot().as_ref().clone());
        let after = engine.load_snapshot();
        assert_eq!(
            b_before,
            Arc::as_ptr(&after.entry("p1").unwrap().batcher),
            "republish must not restart live batchers"
        );
    }
}
