//! Code warm-up (paper Section 3.1.2): before a replica is marked
//! *ready*, a warm-up driver "exercises the real program accurately",
//! forcing the hot paths through their first-touch costs. The paper's
//! Java stack pays JIT compilation; this stack pays PJRT
//! first-execution, lazy allocations and page faults — same mechanism,
//! same cure. Fig. 5's latency stability during rolling updates
//! depends on this.

use super::engine::{Engine, ScoreRequest};
use crate::config::Intent;
use crate::metrics::LatencyHistogram;
use crate::util::rng::Rng;
use anyhow::Result;

/// Result of a warm-up run.
#[derive(Debug, Clone)]
pub struct WarmupReport {
    pub requests: usize,
    /// Latency of the first `cold_window` requests (the JIT/first-
    /// touch regime) vs the last `cold_window` (steady state), in ns.
    pub cold_p50_ns: u64,
    pub warm_p50_ns: u64,
}

/// Drive synthetic traffic through every routable path of the engine
/// until `requests` scorings completed. Synthetic events are generated
/// from each predictor's schema (feature dim), mimicking the paper's
/// subprocess that "generates synthetic data and makes remote calls to
/// the main program".
pub fn warm_up(engine: &Engine, requests: usize, seed: u64) -> Result<WarmupReport> {
    let mut rng = Rng::new(seed);
    let names = engine.registry.names();
    let cold = LatencyHistogram::new();
    let warm = LatencyHistogram::new();
    let window = (requests / 5).max(1);

    // Warm every predictor directly (shadow paths included), not just
    // the currently-routed ones: post-promotion paths must be hot too.
    let mut done = 0usize;
    'outer: loop {
        for name in &names {
            if done >= requests {
                break 'outer;
            }
            let p = engine.predictor(name)?;
            let d = p.feature_dim();
            let features: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let t0 = std::time::Instant::now();
            let _ = p.score(&features, 1, "warmup")?;
            let dt = t0.elapsed().as_nanos() as u64;
            if done < window {
                cold.record(dt);
            } else if done >= requests - window {
                warm.record(dt);
            }
            done += 1;
        }
        if names.is_empty() {
            break;
        }
    }
    // Also exercise the routed scoring path (router + enrichment).
    if !names.is_empty() {
        if let Ok(p) = engine.predictor(&names[0]) {
            let d = p.feature_dim();
            let req = ScoreRequest {
                intent: Intent {
                    tenant: "warmup".into(),
                    ..Intent::default()
                },
                entity: "warmup".into(),
                features: vec![0.0; d],
            };
            // Best effort: routing may 404 for the warmup tenant if no
            // catch-all exists; that is fine.
            let _ = engine.score(&req);
        }
    }
    Ok(WarmupReport {
        requests: done,
        cold_p50_ns: cold.percentile_ns(50.0),
        warm_p50_ns: warm.percentile_ns(50.0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MuseConfig;
    use crate::runtime::{Manifest, ModelPool};
    use std::path::PathBuf;
    use std::sync::Arc;

    const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [m1, m2]
  quantile: identity
"#;

    fn engine() -> Option<Engine> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let pool = Arc::new(ModelPool::new(Manifest::load(root).unwrap()));
        Some(Engine::build(&MuseConfig::from_yaml(CONFIG).unwrap(), pool).unwrap())
    }

    #[test]
    fn warmup_completes_requested_volume() {
        let Some(engine) = engine() else { return };
        let report = warm_up(&engine, 50, 1).unwrap();
        assert_eq!(report.requests, 50);
        assert!(report.cold_p50_ns > 0);
        assert!(report.warm_p50_ns > 0);
    }

    #[test]
    fn steady_state_not_slower_than_cold() {
        let Some(engine) = engine() else { return };
        let report = warm_up(&engine, 300, 2).unwrap();
        // Steady state should be no slower than the cold window
        // (allowing generous noise: 3x).
        assert!(
            report.warm_p50_ns <= report.cold_p50_ns.saturating_mul(3),
            "warm {} vs cold {}",
            report.warm_p50_ns,
            report.cold_p50_ns
        );
    }
}
