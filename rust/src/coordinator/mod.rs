//! The MUSE coordinator — the paper's system contribution (L3):
//! intent routing, the predictor abstraction, the shared-container
//! registry, dynamic batching, the serving engine and the control
//! plane implementing the Fig. 3 model lifecycle.

pub mod batcher;
pub mod deployment;
pub mod engine;
pub mod predictor;
pub mod registry;
pub mod router;
pub mod snapshot;
pub mod tenants;
pub mod warmup;

pub use batcher::{Batcher, BatcherStats};
pub use deployment::{ControlPlane, ShadowValidation};
pub use engine::{Engine, HotCounters, ScoreRequest, ScoreResponse};
pub use predictor::{ExpertSlot, Predictor, QuantileTable, ScoreBatch};
pub use registry::{PredictorRegistry, RegistryStats};
pub use router::{Resolution, Router};
pub use snapshot::{EngineSnapshot, PredictorEntry, TenantRoute};
pub use tenants::{TenantHandle, TenantInterner, DEFAULT_NAME_SHARDS};
pub use warmup::{warm_up, WarmupReport};
