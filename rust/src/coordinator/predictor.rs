//! The runtime *predictor* — the paper's core abstraction
//! (Section 2.2): `p = <M, A, T^Q>` where `M` is the expert set (with
//! per-expert Posterior Corrections `T^C_k`), `A` the aggregation and
//! `T^Q` the quantile mapping. Equation 2:
//!
//! `y = T^Q( A( [T^C_k(m_k(x))] ) )`
//!
//! A predictor *references* shared model containers (it never owns
//! them); its quantile mapping is **tenant-specific** (Section 2.3.3)
//! with a default used until a custom fit is installed. Transform
//! state lives in an immutable [`QuantileTable`] snapshot behind a
//! [`SnapCell`], so the scoring path reads it with one wait-free load
//! (no locks per event or per batch) while the control plane promotes
//! new transformations copy-on-write with zero downtime.

use super::tenants::{TenantHandle, TenantInterner, DEFAULT_NAME_SHARDS};
use crate::runtime::ModelHandle;
use crate::transforms::{
    Aggregation, CompiledPipeline, CompiledStages, PipelineScratch, PosteriorCorrection,
    QuantileMap,
};
use crate::util::slab::HandleSlab;
use crate::util::swap::SnapCell;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// One expert slot: a shared model container + its `T^C_k`.
pub struct ExpertSlot {
    pub handle: ModelHandle,
    pub correction: Option<PosteriorCorrection>,
}

/// The result of scoring a batch through a predictor.
#[derive(Debug, Clone)]
pub struct ScoreBatch {
    /// Business-ready scores (post `T^Q`).
    pub scores: Vec<f64>,
    /// Aggregated, calibrated scores (pre `T^Q`) — recorded to the
    /// data lake for quantile fitting.
    pub raw: Vec<f64>,
}

/// One tenant's installed quantile override: the raw `T^Q` plus the
/// pipeline compiled from it at install time. Published as one unit
/// into the predictor's slab slot, so a probe always sees a map and
/// its own compiled form together.
struct TenantQuantile {
    map: Arc<QuantileMap>,
    pipeline: Arc<CompiledPipeline>,
}

/// The predictor's quantile state as the scoring path sees it: the
/// default `T^Q` (+ compiled default pipeline), published
/// copy-on-write, plus the **slab-indexed per-tenant override slots**
/// shared across table publications.
///
/// The old layout rebuilt this table wholesale per install —
/// recompiling *every* tenant pipeline and recloning both name maps,
/// an O(tenants) republish per first touch that turns a 100k-tenant
/// onboarding storm into O(n²) work on one writer lock. Now an
/// install compiles exactly one pipeline and publishes exactly one
/// slab slot (constant-size segment clone, owning shard only); the
/// default swap still republishes the table, which is constant-size.
///
/// Hot-path contract: a batch group resolves its pipeline with one
/// wait-free slab probe per distinct tenant in the batch — never per
/// event, never a string hash, never a lock.
pub struct QuantileTable {
    default: Arc<QuantileMap>,
    default_pipeline: Arc<CompiledPipeline>,
    /// Handle-indexed override slots, shared (same `Arc`) across
    /// every table this predictor publishes. `None` slots and
    /// out-of-range handles fall back to the default pipeline — the
    /// no-override semantics a brand-new tenant should get.
    slots: Arc<HandleSlab<Arc<TenantQuantile>>>,
    /// The engine-wide interner: string-keyed probes resolve the name
    /// to a handle (without interning) and then index the slab.
    interner: Arc<TenantInterner>,
}

impl QuantileTable {
    /// The installed override slot for a tenant name, if any.
    fn slot_for(&self, tenant: &str) -> Option<Arc<TenantQuantile>> {
        let h = self.interner.lookup(tenant)?;
        self.slots.get(h.index())
    }

    /// The transformation in effect for `tenant`.
    pub fn for_tenant(&self, tenant: &str) -> Arc<QuantileMap> {
        match self.slot_for(tenant) {
            Some(s) => Arc::clone(&s.map),
            None => Arc::clone(&self.default),
        }
    }

    /// The compiled pipeline in effect for `tenant` (one probe; hot
    /// paths do this once per batch group, not per event).
    pub fn pipeline_for(&self, tenant: &str) -> Arc<CompiledPipeline> {
        match self.slot_for(tenant) {
            Some(s) => Arc::clone(&s.pipeline),
            None => Arc::clone(&self.default_pipeline),
        }
    }

    /// The compiled pipeline in effect for an interned tenant handle —
    /// one wait-free slab probe, no hashing, no locks. Out-of-range or
    /// uncovered handles (no override installed) get the default
    /// pipeline, identical to [`QuantileTable::pipeline_for`] on an
    /// unknown name.
    #[inline]
    pub fn pipeline_for_handle(&self, tenant: TenantHandle) -> Arc<CompiledPipeline> {
        match self.slots.get(tenant.index()) {
            Some(s) => Arc::clone(&s.pipeline),
            None => Arc::clone(&self.default_pipeline),
        }
    }

    /// Apply the tenant's `T^Q` to an aggregated raw score.
    pub fn apply(&self, raw: f64, tenant: &str) -> f64 {
        self.for_tenant(tenant).apply(raw)
    }
}

/// Verification-plane introspection (`testkit`): the default map and
/// the override key set are private state the oracle-diff harness must
/// compare against its own model after a command storm — `for_tenant`
/// alone cannot distinguish "override installed" from "fell back to an
/// identical default".
#[cfg(any(test, feature = "testkit"))]
impl QuantileTable {
    /// The default `T^Q` (what tenants without an override get).
    pub fn default_map(&self) -> &Arc<QuantileMap> {
        &self.default
    }

    /// Sorted tenant names carrying a custom `T^Q` override.
    pub fn tenant_names(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        self.slots.for_each(|i, _| {
            if let Some(name) = self.interner.name(TenantHandle::from_index(i)) {
                names.push(name.to_string());
            }
        });
        names.sort();
        names
    }
}

pub struct Predictor {
    pub name: String,
    experts: Vec<ExpertSlot>,
    aggregation: Aggregation,
    /// Stage 1+2 (`T^C` + `A`) compiled once at deploy time and shared
    /// by every tenant's pipeline (the corrections and aggregation are
    /// predictor-level; only `T^Q` varies per tenant).
    stages: Arc<CompiledStages>,
    /// Default + tenant-specific `T^Q`s plus their compiled pipelines,
    /// swapped copy-on-write by the control plane; read wait-free by
    /// the scoring path.
    quantiles: SnapCell<QuantileTable>,
    /// The tenant-override slab behind every published
    /// [`QuantileTable`] (one `Arc`, shared): installs publish one
    /// slot instead of rebuilding the table.
    slots: Arc<HandleSlab<Arc<TenantQuantile>>>,
    feature_dim: usize,
    /// The engine-wide tenant interner (shared via the registry) —
    /// used to key `QuantileTable::by_handle` and exposed so batch
    /// callers resolve a tenant name to a [`TenantHandle`] once.
    tenants: Arc<TenantInterner>,
}

impl Predictor {
    pub fn new(
        name: impl Into<String>,
        experts: Vec<ExpertSlot>,
        aggregation: Aggregation,
        default_quantile: Arc<QuantileMap>,
        tenants: Arc<TenantInterner>,
    ) -> Result<Predictor> {
        let name = name.into();
        ensure!(!experts.is_empty(), "predictor '{name}' needs >= 1 expert");
        if let Some(arity) = aggregation.arity() {
            ensure!(
                arity == experts.len(),
                "predictor '{name}': aggregation arity {arity} != {} experts",
                experts.len()
            );
        }
        let feature_dim = experts[0].handle.feature_dim;
        ensure!(
            experts.iter().all(|e| e.handle.feature_dim == feature_dim),
            "predictor '{name}': experts disagree on feature_dim"
        );
        let corrections: Vec<Option<PosteriorCorrection>> =
            experts.iter().map(|e| e.correction).collect();
        let stages = Arc::new(
            CompiledStages::compile(&corrections, &aggregation)
                .with_context(|| format!("compile pipeline stages for '{name}'"))?,
        );
        let slots: Arc<HandleSlab<Arc<TenantQuantile>>> =
            Arc::new(HandleSlab::with_shards(DEFAULT_NAME_SHARDS));
        Ok(Predictor {
            name,
            experts,
            aggregation,
            quantiles: SnapCell::new(Arc::new(QuantileTable {
                default_pipeline: Arc::new(CompiledPipeline::new(
                    Arc::clone(&stages),
                    Arc::clone(&default_quantile),
                )),
                default: default_quantile,
                slots: Arc::clone(&slots),
                interner: Arc::clone(&tenants),
            })),
            slots,
            stages,
            feature_dim,
            tenants,
        })
    }

    /// The tenant interner this predictor keys handle-indexed state by
    /// (shared engine-wide through the registry).
    pub fn tenants(&self) -> &Arc<TenantInterner> {
        &self.tenants
    }

    pub fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    pub fn expert_names(&self) -> Vec<String> {
        self.experts.iter().map(|e| e.handle.name.clone()).collect()
    }

    pub fn n_experts(&self) -> usize {
        self.experts.len()
    }

    /// The current quantile snapshot. Callers scoring a batch load it
    /// once and apply it per event (see `coordinator::batcher`).
    pub fn quantile_table(&self) -> Arc<QuantileTable> {
        self.quantiles.load()
    }

    /// Install a tenant-specific quantile transformation (the paper's
    /// "custom transformation" promotion, Section 3.1). The tenant's
    /// pipeline is **compiled here**, at control-plane rate, and
    /// published into the tenant's slab slot as one atomic unit (map +
    /// compiled form); takes effect atomically for subsequent
    /// requests. Publishing touches only the handle's owning shard
    /// segment — the table itself is *not* republished, so a 100k
    /// tenant onboarding storm stays O(n) instead of O(n²).
    pub fn install_tenant_quantile(&self, tenant: &str, map: Arc<QuantileMap>) {
        let h = self.tenants.resolve(tenant);
        let pipeline = Arc::new(CompiledPipeline::new(
            Arc::clone(&self.stages),
            Arc::clone(&map),
        ));
        self.slots
            .set(h.index(), Arc::new(TenantQuantile { map, pipeline }));
    }

    /// Replace the default quantile transformation (recompiles the
    /// default pipeline; tenant overrides live in the shared slab and
    /// are carried along untouched — the republished table is
    /// constant-size).
    pub fn set_default_quantile(&self, map: Arc<QuantileMap>) {
        self.quantiles.rcu(|old| {
            (
                Arc::new(QuantileTable {
                    default_pipeline: Arc::new(CompiledPipeline::new(
                        Arc::clone(&self.stages),
                        Arc::clone(&map),
                    )),
                    default: map,
                    slots: Arc::clone(&old.slots),
                    interner: Arc::clone(&old.interner),
                }),
                (),
            )
        });
    }

    /// Whether `tenant` has a custom transformation installed.
    pub fn has_tenant_quantile(&self, tenant: &str) -> bool {
        match self.tenants.lookup(tenant) {
            Some(h) => self.slots.get(h.index()).is_some(),
            None => false,
        }
    }

    /// Apply the tenant's `T^Q` to an already-aggregated raw score.
    /// One-off convenience; batch paths should hold a
    /// [`Predictor::quantile_table`] snapshot instead.
    pub fn apply_quantile(&self, raw: f64, tenant: &str) -> f64 {
        self.quantiles.load().apply(raw, tenant)
    }

    /// Score `n` events for `tenant` (Eq. 2 end to end).
    pub fn score(&self, features: &[f32], n: usize, tenant: &str) -> Result<ScoreBatch> {
        let raw = self.score_raw(features, n)?;
        let table = self.quantiles.load();
        let q = table.for_tenant(tenant);
        let scores = raw.iter().map(|&s| q.apply(s)).collect();
        Ok(ScoreBatch { scores, raw })
    }

    /// The pre-`T^Q` pipeline: expert inference -> `T^C` -> `A`.
    /// Exposed for quantile fitting (which needs the source
    /// distribution) and the Fig. 4 "raw" baseline.
    pub fn score_raw(&self, features: &[f32], n: usize) -> Result<Vec<f64>> {
        ensure!(
            features.len() == n * self.feature_dim,
            "predictor '{}': got {} floats for {n} events of dim {}",
            self.name,
            features.len(),
            self.feature_dim
        );
        if n == 0 {
            return Ok(vec![]);
        }
        // Expert inference fans out to all containers concurrently —
        // they are independent threads, so the per-event service time
        // is the max over experts rather than the sum (EXPERIMENTS.md
        // "Perf log", step 2: this halved ensemble latency on the
        // 2-core testbed and cut the saturated p99 tail).
        let tickets: Vec<_> = self
            .experts
            .iter()
            .map(|e| e.handle.infer_async(features, n))
            .collect::<Result<Vec<_>>>()?;
        let mut expert_scores: Vec<Vec<f32>> = Vec::with_capacity(self.experts.len());
        for (t, e) in tickets.into_iter().zip(&self.experts) {
            expert_scores.push(
                t.wait()
                    .with_context(|| format!("expert '{}' inference", e.handle.name))?,
            );
        }
        // T^C then A, per event.
        let k = self.experts.len();
        let mut calibrated = vec![0.0f64; k];
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            for (j, e) in self.experts.iter().enumerate() {
                let s = expert_scores[j][i] as f64;
                calibrated[j] = match &e.correction {
                    Some(c) => c.apply(s),
                    None => s,
                };
            }
            out.push(self.aggregation.apply_unchecked(&calibrated));
        }
        Ok(out)
    }

    /// The compiled stage-1+2 kernel shared by this predictor's
    /// tenant pipelines.
    pub fn stages(&self) -> &Arc<CompiledStages> {
        &self.stages
    }

    /// Compiled batch scoring, stages 1+2: expert inference fans out
    /// asynchronously, results land in `scratch`'s flat SoA lanes (no
    /// per-batch `Vec<Vec<f32>>` staging), then the branch-free kernel
    /// writes the raw (pre-`T^Q`) scores into `raw_out` (cleared
    /// first). This is the hot batch path; [`Predictor::score_raw`]
    /// stays as the staged reference oracle.
    pub fn score_batch_raw_compiled(
        &self,
        features: &[f32],
        n: usize,
        scratch: &mut PipelineScratch,
        raw_out: &mut Vec<f64>,
    ) -> Result<()> {
        ensure!(
            features.len() == n * self.feature_dim,
            "predictor '{}': got {} floats for {n} events of dim {}",
            self.name,
            features.len(),
            self.feature_dim
        );
        raw_out.clear();
        let k = self.experts.len();
        scratch.begin(k, n);
        if n == 0 {
            return Ok(());
        }
        // One feature copy for the whole ensemble: the batch is cloned
        // into a shared `Arc` once and every expert's dispatch borrows
        // it (`infer_async` would copy the slice per expert). For a
        // k-expert predictor this removes k-1 batch-sized copies per
        // dispatch from the hot path.
        let shared = Arc::new(features.to_vec());
        let tickets: Vec<_> = self
            .experts
            .iter()
            .map(|e| e.handle.infer_async_shared(Arc::clone(&shared), n))
            .collect::<Result<Vec<_>>>()?;
        for (j, (t, e)) in tickets.into_iter().zip(&self.experts).enumerate() {
            let scores = t
                .wait()
                .with_context(|| format!("expert '{}' inference", e.handle.name))?;
            ensure!(
                scores.len() == n,
                "expert '{}' returned {} scores for {n} events",
                e.handle.name,
                scores.len()
            );
            scratch.lane_mut(j).copy_from_slice(&scores);
        }
        self.stages.raw_into(scratch, raw_out);
        Ok(())
    }

    /// Compiled end-to-end batch scoring for one tenant: raw and final
    /// scores with exactly **one** quantile-table snapshot load and
    /// **one** tenant-pipeline probe for the whole batch — the
    /// zero-per-event-lookup contract of `Engine::score_batch`.
    pub fn score_batch_for_tenant(
        &self,
        features: &[f32],
        n: usize,
        tenant: &str,
        scratch: &mut PipelineScratch,
        raw_out: &mut Vec<f64>,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        self.score_batch_raw_compiled(features, n, scratch, raw_out)?;
        out.clear();
        let table = self.quantiles.load();
        table.pipeline_for(tenant).finalize_into(raw_out, out);
        Ok(())
    }

    /// [`Predictor::score_batch_for_tenant`] keyed by an interned
    /// handle: the per-batch tenant-pipeline resolution is an array
    /// index instead of a string hash. This is the engine's batch hot
    /// path; the string variant remains for callers without a handle.
    pub fn score_batch_for_tenant_handle(
        &self,
        features: &[f32],
        n: usize,
        tenant: TenantHandle,
        scratch: &mut PipelineScratch,
        raw_out: &mut Vec<f64>,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        self.score_batch_raw_compiled(features, n, scratch, raw_out)?;
        out.clear();
        let table = self.quantiles.load();
        table.pipeline_for_handle(tenant).finalize_into(raw_out, out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Manifest, ModelPool};
    use crate::transforms::ReferenceDistribution;
    use std::path::PathBuf;

    fn pool() -> Option<Arc<ModelPool>> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Arc::new(ModelPool::new(Manifest::load(root).unwrap())))
    }

    fn ensemble(pool: &ModelPool, models: &[&str]) -> Predictor {
        let experts: Vec<ExpertSlot> = models
            .iter()
            .map(|m| {
                let handle = pool.acquire(m).unwrap();
                let beta = handle.beta;
                ExpertSlot {
                    handle,
                    correction: Some(PosteriorCorrection::new(beta).unwrap()),
                }
            })
            .collect();
        let k = experts.len();
        Predictor::new(
            format!("test-{}", models.join("-")),
            experts,
            Aggregation::weighted(vec![1.0; k]).unwrap(),
            QuantileMap::identity(101).unwrap().shared(),
            Arc::new(TenantInterner::new()),
        )
        .unwrap()
    }

    #[test]
    fn scores_are_bounded_and_deterministic() {
        let Some(pool) = pool() else { return };
        let p = ensemble(&pool, &["m1", "m2"]);
        let mut rng = crate::util::rng::Rng::new(1);
        let d = p.feature_dim();
        let features: Vec<f32> = (0..8 * d).map(|_| rng.normal() as f32).collect();
        let a = p.score(&features, 8, "bank1").unwrap();
        let b = p.score(&features, 8, "bank1").unwrap();
        assert_eq!(a.scores, b.scores);
        for s in &a.scores {
            assert!((0.0..=1.0).contains(s));
        }
        assert_eq!(a.raw.len(), 8);
    }

    #[test]
    fn posterior_correction_deflates_raw_scores() {
        let Some(pool) = pool() else { return };
        // Same model with and without correction: corrected aggregate
        // must be <= uncorrected (scores deflate towards the true
        // posterior under beta < 1).
        let with = ensemble(&pool, &["m3"]);
        let without = Predictor::new(
            "no-pc",
            vec![ExpertSlot {
                handle: pool.acquire("m3").unwrap(),
                correction: None,
            }],
            Aggregation::Identity,
            QuantileMap::identity(101).unwrap().shared(),
            Arc::new(TenantInterner::new()),
        )
        .unwrap();
        let mut rng = crate::util::rng::Rng::new(2);
        let d = with.feature_dim();
        let features: Vec<f32> = (0..16 * d).map(|_| rng.normal() as f32).collect();
        let c = with.score_raw(&features, 16).unwrap();
        let u = without.score_raw(&features, 16).unwrap();
        for (ci, ui) in c.iter().zip(&u) {
            assert!(ci <= ui, "corrected {ci} > uncorrected {ui}");
        }
    }

    #[test]
    fn tenant_specific_quantile_overrides_default() {
        let Some(pool) = pool() else { return };
        let p = ensemble(&pool, &["m1"]);
        let refd = ReferenceDistribution::fraud_default();
        // Custom map that pushes everything to ~1.
        let custom = QuantileMap::new(vec![0.0, 1.0], vec![0.99, 1.0]).unwrap().shared();
        p.install_tenant_quantile("bank1", custom);
        let d = p.feature_dim();
        let features = vec![0.1f32; d];
        let bank1 = p.score(&features, 1, "bank1").unwrap();
        let other = p.score(&features, 1, "bank2").unwrap();
        assert!(bank1.scores[0] >= 0.99);
        assert!(other.scores[0] < 0.99); // identity default
        assert!(p.has_tenant_quantile("bank1"));
        assert!(!p.has_tenant_quantile("bank2"));
        let _ = refd;
    }

    #[test]
    fn quantile_swap_is_live() {
        let Some(pool) = pool() else { return };
        let p = ensemble(&pool, &["m1"]);
        let d = p.feature_dim();
        let features = vec![0.0f32; d];
        let before = p.score(&features, 1, "t").unwrap().scores[0];
        p.set_default_quantile(
            QuantileMap::new(vec![0.0, 1.0], vec![0.5, 1.0]).unwrap().shared(),
        );
        let after = p.score(&features, 1, "t").unwrap().scores[0];
        assert!(after >= 0.5);
        assert!(before < 0.5);
    }

    #[test]
    fn default_swap_preserves_tenant_overrides() {
        let Some(pool) = pool() else { return };
        let p = ensemble(&pool, &["m1"]);
        p.install_tenant_quantile(
            "vip",
            QuantileMap::new(vec![0.0, 1.0], vec![0.9, 1.0]).unwrap().shared(),
        );
        p.set_default_quantile(
            QuantileMap::new(vec![0.0, 1.0], vec![0.5, 1.0]).unwrap().shared(),
        );
        // Copy-on-write table swap must carry the vip override along.
        assert!(p.has_tenant_quantile("vip"));
        let t = p.quantile_table();
        assert!(t.apply(0.0, "vip") >= 0.9);
        assert!(t.apply(0.0, "other") >= 0.5);
    }

    #[test]
    fn raw_equals_transformed_under_identity() {
        let Some(pool) = pool() else { return };
        let p = ensemble(&pool, &["m1", "m2", "m3"]);
        let mut rng = crate::util::rng::Rng::new(3);
        let d = p.feature_dim();
        let features: Vec<f32> = (0..4 * d).map(|_| rng.normal() as f32).collect();
        let batch = p.score(&features, 4, "t").unwrap();
        for (s, r) in batch.scores.iter().zip(&batch.raw) {
            assert!((s - r).abs() < 1e-9, "identity T^Q must not change scores");
        }
    }

    #[test]
    fn feature_len_validation() {
        let Some(pool) = pool() else { return };
        let p = ensemble(&pool, &["m1"]);
        assert!(p.score(&[0.0; 3], 1, "t").is_err());
        assert_eq!(p.score(&[], 0, "t").unwrap().scores.len(), 0);
    }

    #[test]
    fn compiled_batch_path_matches_staged_path() {
        let Some(pool) = pool() else { return };
        let p = ensemble(&pool, &["m1", "m2", "m3"]);
        p.install_tenant_quantile(
            "vip",
            QuantileMap::new(vec![0.0, 1.0], vec![0.5, 1.0]).unwrap().shared(),
        );
        let d = p.feature_dim();
        let mut rng = crate::util::rng::Rng::new(11);
        let n = 40;
        let feats: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut scratch = crate::transforms::PipelineScratch::default();
        let (mut raw, mut out) = (Vec::new(), Vec::new());
        for tenant in ["vip", "other"] {
            p.score_batch_for_tenant(&feats, n, tenant, &mut scratch, &mut raw, &mut out)
                .unwrap();
            let staged = p.score(&feats, n, tenant).unwrap();
            assert_eq!(out.len(), n);
            for i in 0..n {
                assert!(
                    (raw[i] - staged.raw[i]).abs() <= 1e-12,
                    "raw[{i}]: compiled {} vs staged {}",
                    raw[i],
                    staged.raw[i]
                );
                assert!(
                    (out[i] - staged.scores[i]).abs() <= 1e-12,
                    "final[{i}]: compiled {} vs staged {} ({tenant})",
                    out[i],
                    staged.scores[i]
                );
            }
        }
    }

    #[test]
    fn pipeline_probe_tracks_tenant_installs() {
        let Some(pool) = pool() else { return };
        let p = ensemble(&pool, &["m1"]);
        p.install_tenant_quantile(
            "vip",
            QuantileMap::new(vec![0.0, 1.0], vec![0.9, 1.0]).unwrap().shared(),
        );
        let t = p.quantile_table();
        // One probe resolves the compiled pipeline; its table is the
        // same object the raw map lookup returns.
        let vip_pipe = t.pipeline_for("vip");
        assert!(Arc::ptr_eq(vip_pipe.table(), &t.for_tenant("vip")));
        assert!((t.pipeline_for("vip").finalize_one(0.0) - 0.9).abs() < 1e-12);
        assert!(t.pipeline_for("other").finalize_one(0.0) < 0.9);
        // Default-swap recompiles the default pipeline, keeps vip.
        p.set_default_quantile(
            QuantileMap::new(vec![0.0, 1.0], vec![0.5, 1.0]).unwrap().shared(),
        );
        let t = p.quantile_table();
        assert!((t.pipeline_for("other").finalize_one(0.0) - 0.5).abs() < 1e-12);
        assert!((t.pipeline_for("vip").finalize_one(0.0) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn arity_mismatch_rejected_at_build() {
        let Some(pool) = pool() else { return };
        let experts = vec![ExpertSlot {
            handle: pool.acquire("m1").unwrap(),
            correction: None,
        }];
        let r = Predictor::new(
            "bad",
            experts,
            Aggregation::weighted(vec![1.0, 1.0]).unwrap(),
            QuantileMap::identity(3).unwrap().shared(),
            Arc::new(TenantInterner::new()),
        );
        assert!(r.is_err());
    }

    #[test]
    fn handle_keyed_pipeline_matches_string_keyed() {
        let Some(pool) = pool() else { return };
        let p = ensemble(&pool, &["m1", "m2"]);
        // Handle interned *before* the override exists: the slot
        // publish on install must cover it.
        let early = p.tenants().resolve("vip");
        p.install_tenant_quantile(
            "vip",
            QuantileMap::new(vec![0.0, 1.0], vec![0.9, 1.0]).unwrap().shared(),
        );
        let t = p.quantile_table();
        assert!(Arc::ptr_eq(
            &t.pipeline_for_handle(early),
            &t.pipeline_for("vip")
        ));
        // A handle with no override installed -> default pipeline,
        // same as an unknown name.
        let late = p.tenants().resolve("latecomer");
        assert!(Arc::ptr_eq(
            &t.pipeline_for_handle(late),
            &t.pipeline_for("latecomer")
        ));
        assert!(Arc::ptr_eq(
            &t.pipeline_for_handle(TenantHandle::INVALID),
            &t.pipeline_for("no-such-tenant")
        ));
        // End to end: handle-keyed batch scoring is bitwise equal to
        // the string-keyed path for both override and default tenants.
        let d = p.feature_dim();
        let mut rng = crate::util::rng::Rng::new(17);
        let n = 23;
        let feats: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut scratch = crate::transforms::PipelineScratch::default();
        let (mut raw_h, mut out_h) = (Vec::new(), Vec::new());
        let (mut raw_s, mut out_s) = (Vec::new(), Vec::new());
        for tenant in ["vip", "latecomer"] {
            let h = p.tenants().resolve(tenant);
            p.score_batch_for_tenant_handle(&feats, n, h, &mut scratch, &mut raw_h, &mut out_h)
                .unwrap();
            p.score_batch_for_tenant(&feats, n, tenant, &mut scratch, &mut raw_s, &mut out_s)
                .unwrap();
            assert_eq!(raw_h, raw_s, "{tenant}: raw scores must be bitwise equal");
            assert_eq!(out_h, out_s, "{tenant}: final scores must be bitwise equal");
        }
    }
}
