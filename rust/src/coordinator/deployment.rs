//! The control plane: the model lifecycle of paper Fig. 3 as
//! first-class operations — all server-side, zero client interaction
//! (Section 2.5.1's "client-free intervention" list).
//!
//! * `fit_default_quantile` — cold-start `T^Q_{v0}` from the Beta-
//!   mixture prior (Section 2.4).
//! * `fit_custom_quantile` — tenant-specific `T^Q_{v1}` from live
//!   (unlabeled) scores, gated by the Eq. 5 sample-size bound.
//! * `shadow_deploy` — deploy a predictor + shadow rule (validation
//!   against live traffic without affecting responses).
//! * `validate_shadow` — distribution-stability check of the shadow's
//!   scores against the target reference.
//! * `promote` — atomically swap the live scoring rule to the shadow
//!   (transparent model switching), and `decommission` the old one.
//!
//! Every operation that deploys a predictor or installs a quantile
//! map also **compiles** the affected per-tenant transform pipelines
//! (`transforms::pipeline`) at this control-plane rate — deploy and
//! `shadow_deploy` compile the predictor's stage kernel, the
//! quantile-fit/install paths recompile the tenant's `T^Q` tail — so
//! the data plane only ever replays pre-resolved, branch-free
//! pipelines (docs/ARCHITECTURE.md "Pipeline compilation").

use super::engine::Engine;
use crate::config::{Condition, PredictorConfig, ScoringRule, ShadowRule};
use crate::coldstart::{fit_mixture, FitConfig};
use crate::transforms::{quantile_fit, QuantileMap, ReferenceDistribution};
use crate::util::dataset::Dataset;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// Validation report for a shadow predictor (Section 3.1: "deployed in
/// shadow mode for validation").
#[derive(Debug, Clone)]
pub struct ShadowValidation {
    pub predictor: String,
    pub tenant: String,
    pub samples: usize,
    /// Max absolute per-bin deviation (share) vs the target reference.
    pub max_bin_deviation: f64,
    pub pass: bool,
}

pub struct ControlPlane<'e> {
    pub engine: &'e Engine,
}

impl<'e> ControlPlane<'e> {
    pub fn new(engine: &'e Engine) -> Self {
        ControlPlane { engine }
    }

    /// Cold start (Section 2.4): score the experts' combined training
    /// data through the predictor's raw pipeline, fit the bimodal Beta
    /// mixture (Eqs. 6-8) as a smooth stand-in for `S`, and install
    /// `T^Q_{v0}` = (mixture quantiles -> reference quantiles) as the
    /// predictor's default transformation.
    pub fn fit_default_quantile(
        &self,
        predictor: &str,
        training: &Dataset,
        reference: &ReferenceDistribution,
        fit_cfg: &FitConfig,
    ) -> Result<Arc<QuantileMap>> {
        let p = self.engine.predictor(predictor)?;
        let raw = p
            .score_raw(&training.features, training.n)
            .context("score training pool")?;
        let w = training.positive_rate();
        let fit = fit_mixture(&raw, w, fit_cfg).context("beta-mixture fit")?;
        let n_points = self.engine.quantile_points;
        let src = fit.mixture.quantile_grid(n_points);
        let refq = reference.quantile_grid(n_points);
        let map = QuantileMap::new(src, refq)?.shared();
        p.set_default_quantile(Arc::clone(&map));
        Ok(map)
    }

    /// Custom per-tenant fit (Section 2.3.3): estimate the tenant's
    /// source quantiles for `predictor`, check the Eq. 5 volume gate,
    /// fit against the reference, and install atomically.
    ///
    /// When the lifecycle autopilot is tracking the pair **and** its
    /// merged streaming sketch already satisfies the Eq. 5 bound, the
    /// source quantiles come from the sketch — O(sketch items)
    /// regardless of traffic volume. Otherwise (no autopilot, pair not
    /// tracked, or the sketch was recently reset by a fit/window
    /// rotation and holds fewer samples than Eq. 5 demands) the fit
    /// falls back to replaying the tenant's raw scores from the data
    /// lake — the original path, which may still hold the deeper
    /// history the sketch no longer does.
    pub fn fit_custom_quantile(
        &self,
        predictor: &str,
        tenant: &str,
        reference: &ReferenceDistribution,
        alert_rate: f64,
        delta: f64,
        z: f64,
    ) -> Result<Arc<QuantileMap>> {
        let n_points = self.engine.quantile_points;
        let refq = reference.quantile_grid(n_points);
        let need = quantile_fit::required_samples(alert_rate, delta, z)?;
        let sketched = self
            .engine
            .lifecycle
            .as_ref()
            .and_then(|hub| hub.sketch_summary(predictor, tenant))
            .filter(|s| s.total_weight() >= need);
        let map = match sketched {
            Some(summary) => summary.fit_quantile_map_gated(&refq, alert_rate, delta, z)?,
            None => {
                let raw = self.engine.lake.raw_scores(tenant, predictor);
                quantile_fit::fit_gated(&raw, &refq, alert_rate, delta, z)?
            }
        }
        .shared();
        self.engine
            .predictor(predictor)?
            .install_tenant_quantile(tenant, Arc::clone(&map));
        Ok(map)
    }

    /// Install a pre-fitted custom transformation directly (offline
    /// fits; used by the harnesses).
    pub fn install_custom_quantile(
        &self,
        predictor: &str,
        tenant: &str,
        map: Arc<QuantileMap>,
    ) -> Result<()> {
        self.engine
            .predictor(predictor)?
            .install_tenant_quantile(tenant, map);
        Ok(())
    }

    /// Deploy `cfg` and mirror `tenant`'s traffic to it (Fig. 3 step:
    /// "deployed in shadow mode").
    pub fn shadow_deploy(
        &self,
        cfg: &PredictorConfig,
        tenant: &str,
        quantile: Arc<QuantileMap>,
    ) -> Result<()> {
        self.engine.registry.deploy(cfg, quantile)?;
        let mut routing = self.engine.router.snapshot().as_ref().clone();
        routing.shadow_rules.push(ShadowRule {
            description: format!("shadow {} for {tenant}", cfg.name),
            condition: Condition {
                tenants: vec![tenant.to_string()],
                ..Condition::default()
            },
            target_predictors: vec![cfg.name.as_str().into()],
        });
        self.engine.router.swap(routing);
        self.engine.republish();
        Ok(())
    }

    /// Validate a shadow predictor's score distribution against the
    /// target reference: max per-bin share deviation <= `tolerance`.
    pub fn validate_shadow(
        &self,
        predictor: &str,
        tenant: &str,
        reference: &ReferenceDistribution,
        min_samples: usize,
        tolerance: f64,
    ) -> Result<ShadowValidation> {
        let scores = self.engine.lake.final_scores(tenant, predictor);
        ensure!(
            scores.len() >= min_samples,
            "shadow '{predictor}' has only {} samples (need {min_samples})",
            scores.len()
        );
        let n_bins = 10;
        let counts = crate::util::stats::bin_counts(&scores, n_bins);
        let target = reference.bin_shares(n_bins);
        let total: u64 = counts.iter().sum();
        let max_bin_deviation = counts
            .iter()
            .zip(&target)
            .map(|(&c, &t)| (c as f64 / total as f64 - t).abs())
            .fold(0.0f64, f64::max);
        Ok(ShadowValidation {
            predictor: predictor.to_string(),
            tenant: tenant.to_string(),
            samples: scores.len(),
            max_bin_deviation,
            pass: max_bin_deviation <= tolerance,
        })
    }

    /// Promote `new_predictor` to live for `tenant`: rewrite the
    /// tenant's scoring rule (first match) to target it and drop its
    /// shadow rules. A single server-side snapshot publication — "the
    /// transition is transparent from the client's perspective", and
    /// requests in flight finish on the snapshot they started with.
    pub fn promote(&self, tenant: &str, new_predictor: &str) -> Result<()> {
        ensure!(
            self.engine.registry.get(new_predictor).is_some(),
            "cannot promote undeployed predictor '{new_predictor}'"
        );
        let mut routing = self.engine.router.snapshot().as_ref().clone();
        let intent = crate::config::Intent {
            tenant: tenant.to_string(),
            ..Default::default()
        };
        let mut rewritten = false;
        for rule in routing.scoring_rules.iter_mut() {
            if rule.condition.matches(&intent) {
                // If the tenant currently rides a broad rule, give it
                // a dedicated rule instead of hijacking the broad one.
                if rule.condition.tenants == vec![tenant.to_string()] {
                    rule.target_predictor = new_predictor.into();
                } else {
                    routing.scoring_rules.insert(
                        0,
                        ScoringRule {
                            description: format!("promoted {new_predictor} for {tenant}"),
                            condition: Condition {
                                tenants: vec![tenant.to_string()],
                                ..Condition::default()
                            },
                            target_predictor: new_predictor.into(),
                        },
                    );
                }
                rewritten = true;
                break;
            }
        }
        ensure!(rewritten, "no scoring rule matches tenant '{tenant}'");
        routing
            .shadow_rules
            .retain(|r| !r.target_predictors.iter().any(|t| &**t == new_predictor));
        self.engine.router.swap(routing);
        self.engine.republish();
        Ok(())
    }

    /// Decommission a predictor (Fig. 3 final step): remove any rules
    /// referencing it, publish the shrunken snapshot (which also shuts
    /// down the predictor's batcher), then release its containers.
    pub fn decommission(&self, predictor: &str) -> Result<()> {
        let mut routing = self.engine.router.snapshot().as_ref().clone();
        routing
            .scoring_rules
            .retain(|r| &*r.target_predictor != predictor);
        for rule in routing.shadow_rules.iter_mut() {
            rule.target_predictors.retain(|t| &**t != predictor);
        }
        routing.shadow_rules.retain(|r| !r.target_predictors.is_empty());
        self.engine.router.swap(routing);
        let out = self.engine.registry.decommission(predictor);
        self.engine.republish();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Intent, MuseConfig, QuantileMode};
    use crate::coordinator::engine::ScoreRequest;
    use crate::runtime::{Manifest, ModelPool};
    use std::path::PathBuf;

    const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 v1"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "p1"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p1"
predictors:
- name: p1
  experts: [m1, m2]
  quantile: identity
"#;

    fn engine() -> Option<Engine> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let pool = Arc::new(ModelPool::new(Manifest::load(root).unwrap()));
        Some(Engine::build(&MuseConfig::from_yaml(CONFIG).unwrap(), pool).unwrap())
    }

    fn p2_cfg() -> PredictorConfig {
        PredictorConfig {
            name: "p2".into(),
            experts: vec!["m1".into(), "m2".into(), "m3".into()],
            weights: vec![1.0; 3],
            quantile_mode: QuantileMode::Identity,
            reference: "fraud-default".into(),
            posterior_correction: true,
        }
    }

    fn drive_traffic(engine: &Engine, n: usize, seed: u64) {
        let d = engine.predictor("p1").unwrap().feature_dim();
        let mut rng = crate::util::rng::Rng::new(seed);
        for i in 0..n {
            let req = ScoreRequest {
                intent: Intent {
                    tenant: "bank1".into(),
                    ..Intent::default()
                },
                entity: format!("e{i}"),
                features: (0..d).map(|_| rng.normal() as f32).collect(),
            };
            engine.score(&req).unwrap();
        }
        engine.drain_shadows();
    }

    #[test]
    fn full_fig3_lifecycle() {
        let Some(engine) = engine() else { return };
        let cp = ControlPlane::new(&engine);
        let idq = QuantileMap::identity(33).unwrap().shared();

        // 1. shadow deploy p2 for bank1.
        cp.shadow_deploy(&p2_cfg(), "bank1", idq).unwrap();
        assert_eq!(engine.registry.stats().predictors, 2);

        // 2. traffic flows: live to p1, mirrored to p2.
        drive_traffic(&engine, 64, 1);
        assert_eq!(engine.lake.raw_scores("bank1", "p2").len(), 64);

        // 3. promote p2 to live; shadow rule dropped.
        cp.promote("bank1", "p2").unwrap();
        let res = engine
            .router
            .resolve(&Intent {
                tenant: "bank1".into(),
                ..Intent::default()
            })
            .unwrap();
        assert_eq!(&*res.live, "p2");
        assert!(res.shadows.is_empty());

        // 4. decommission p1 — its rules go away; other tenants now
        //    route via remaining rules.
        cp.decommission("p1").unwrap();
        assert!(engine.registry.get("p1").is_none());
        // Shared containers m1, m2 survive for p2 (+ m3).
        assert_eq!(engine.registry.stats().pool.live_containers, 3);
        // bank1 still served, zero downtime.
        drive_traffic_p2(&engine);
    }

    fn drive_traffic_p2(engine: &Engine) {
        let d = engine.predictor("p2").unwrap().feature_dim();
        let req = ScoreRequest {
            intent: Intent {
                tenant: "bank1".into(),
                ..Intent::default()
            },
            entity: "e".into(),
            features: vec![0.0; d],
        };
        assert!(engine.score(&req).is_ok());
    }

    #[test]
    fn custom_fit_gated_by_eq5() {
        let Some(engine) = engine() else { return };
        let cp = ControlPlane::new(&engine);
        drive_traffic(&engine, 50, 2);
        let reference = ReferenceDistribution::fraud_default();
        // 50 samples is far below the Eq. 5 requirement at a=1%.
        let err = cp
            .fit_custom_quantile("p1", "bank1", &reference, 0.01, 0.2, 1.96)
            .unwrap_err();
        assert!(err.to_string().contains("Eq.5"), "{err}");
        // With a lax gate it fits and installs.
        drive_traffic(&engine, 1100, 3);
        cp.fit_custom_quantile("p1", "bank1", &reference, 0.5, 0.5, 1.0)
            .unwrap();
        assert!(engine.predictor("p1").unwrap().has_tenant_quantile("bank1"));
    }

    #[test]
    fn custom_fit_consumes_sketch_not_lake_replay() {
        // The autopilot's sketch is the fit source when it tracks the
        // pair: cap the lake far below the fit's sample needs — a lake
        // replay could not possibly fit, so success proves the sketch
        // path. Runs on synthetic sim-dialect artifacts (no `make
        // artifacts` needed).
        use crate::coordinator::engine::ScoreRequest;
        use crate::runtime::SimArtifacts;
        let fix = SimArtifacts::in_temp().unwrap();
        let yaml = r#"
routing:
  scoringRules:
  - description: "acme dedicated"
    condition:
      tenants: ["acme"]
    targetPredictorName: "duo"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "duo"
predictors:
- name: duo
  experts: [s1, s2]
  quantile: custom
server:
  workers: 2
  lakeMaxRecords: 64
lifecycle:
  enabled: true
  tenants: ["acme"]
  autoDiscover: false
  alertRate: 0.1
  delta: 0.05
  minValidationSamples: 8
"#;
        let pool = Arc::new(crate::runtime::ModelPool::new(fix.manifest().unwrap()));
        let engine =
            Engine::build(&MuseConfig::from_yaml(yaml).unwrap(), pool).unwrap();
        let hub = engine.lifecycle.as_ref().unwrap();
        hub.tick(&engine).unwrap(); // register the pair's feed
        let mut wl = crate::simulator::Workload::new(
            crate::simulator::TenantProfile::new("acme", 3, 0.3, 0.1),
            9,
        );
        for b in 0..6 {
            let reqs: Vec<ScoreRequest> = (0..256)
                .map(|i| ScoreRequest {
                    intent: Intent {
                        tenant: "acme".into(),
                        ..Intent::default()
                    },
                    entity: format!("s{b}-{i}"),
                    features: wl.next_event().features,
                })
                .collect();
            engine.score_batch(&reqs).unwrap();
            hub.tick(&engine).unwrap();
        }
        // The capped lake kept only 64 records — not even one sample
        // per quantile point — while the sketch observed ~1.5k.
        assert_eq!(engine.lake.len(), 64);
        let summary = hub.sketch_summary("duo", "acme").unwrap();
        assert!(summary.total_weight() > 1000, "{}", summary.total_weight());
        let cp = ControlPlane::new(&engine);
        let reference = ReferenceDistribution::fraud_default();
        cp.fit_custom_quantile("duo", "acme", &reference, 0.5, 0.5, 1.0)
            .unwrap();
        assert!(engine.predictor("duo").unwrap().has_tenant_quantile("acme"));
        engine.drain_shadows();
    }

    #[test]
    fn promote_unknown_predictor_fails() {
        let Some(engine) = engine() else { return };
        let cp = ControlPlane::new(&engine);
        assert!(cp.promote("bank1", "ghost").is_err());
    }

    #[test]
    fn promote_on_broad_rule_inserts_dedicated_rule() {
        let Some(engine) = engine() else { return };
        let cp = ControlPlane::new(&engine);
        cp.shadow_deploy(&p2_cfg(), "otherbank", QuantileMap::identity(3).unwrap().shared())
            .unwrap();
        // otherbank currently matches only the catch-all.
        cp.promote("otherbank", "p2").unwrap();
        let res = engine
            .router
            .resolve(&Intent {
                tenant: "otherbank".into(),
                ..Intent::default()
            })
            .unwrap();
        assert_eq!(&*res.live, "p2");
        // bank1 unaffected.
        let res = engine
            .router
            .resolve(&Intent {
                tenant: "bank1".into(),
                ..Intent::default()
            })
            .unwrap();
        assert_eq!(&*res.live, "p1");
    }

    #[test]
    fn shadow_validation_reports_deviation() {
        let Some(engine) = engine() else { return };
        let cp = ControlPlane::new(&engine);
        cp.shadow_deploy(&p2_cfg(), "bank1", QuantileMap::identity(33).unwrap().shared())
            .unwrap();
        drive_traffic(&engine, 200, 4);
        let reference = ReferenceDistribution::fraud_default();
        let v = cp
            .validate_shadow("p2", "bank1", &reference, 100, 0.5)
            .unwrap();
        assert_eq!(v.samples, 200);
        assert!(v.max_bin_deviation >= 0.0);
        // Identity transform on raw fraud scores concentrates in bin 0
        // (~98% legit) vs target ~70%: deviation ~0.3 => tolerant pass,
        // strict fail.
        let strict = cp
            .validate_shadow("p2", "bank1", &reference, 100, 0.05)
            .unwrap();
        assert!(!strict.pass, "identity shadow should fail strict validation");
        // Not enough samples is an error.
        assert!(cp.validate_shadow("p2", "bank1", &reference, 10_000, 0.5).is_err());
    }
}
