//! Dynamic batching: concurrent single-event requests to the same
//! predictor are coalesced into one batched inference call (amortising
//! the PJRT dispatch overhead), bounded by a max batch size and a max
//! queueing delay so tail latency stays inside the SLO.
//!
//! The paper's serving layer gets its throughput from Triton-side
//! batching; here the coordinator owns it, which also exercises the
//! AOT batch variants (1/16/64/256) produced by the compile path.
//!
//! Transform execution inside the worker is the **compiled pipeline**
//! (`transforms::pipeline`): expert scores land in a reusable SoA
//! scratch, the branch-free kernel aggregates them, and each tenant's
//! `T^Q` tail is resolved once per (batch, tenant) group — the staged
//! per-event path survives only as the reference oracle
//! (`Predictor::score_raw`).

use super::predictor::Predictor;
use crate::transforms::{CompiledPipeline, PipelineScratch};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

struct Pending {
    features: Vec<f32>,
    tenant: String,
    reply: mpsc::SyncSender<Result<(f64, f64)>>, // (final, raw)
}

/// A dynamic batcher bound to one predictor.
pub struct Batcher {
    queue_tx: mpsc::Sender<Pending>,
    worker: Option<thread::JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    stats: Arc<Mutex<BatcherStats>>,
    pub max_batch: usize,
    pub max_delay: Duration,
}

/// Rolling batcher statistics.
#[derive(Debug, Default, Clone, Copy)]
pub struct BatcherStats {
    pub batches: u64,
    pub events: u64,
}

impl Batcher {
    pub fn new(predictor: Arc<Predictor>, max_batch: usize, max_delay: Duration) -> Batcher {
        assert!(max_batch >= 1);
        let (tx, rx) = mpsc::channel::<Pending>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = Arc::clone(&shutdown);
        let stats = Arc::new(Mutex::new(BatcherStats::default()));
        let stats_w = Arc::clone(&stats);
        let worker = thread::Builder::new()
            .name(format!("batcher-{}", predictor.name))
            .spawn(move || batcher_main(predictor, rx, stop, max_batch, max_delay, stats_w))
            .expect("spawn batcher");
        Batcher {
            queue_tx: tx,
            worker: Some(worker),
            shutdown,
            stats,
            max_batch,
            max_delay,
        }
    }

    /// Batching effectiveness so far (batches vs events coalesced).
    pub fn stats(&self) -> BatcherStats {
        *self.stats.lock().unwrap()
    }

    /// Submit one event; blocks until its batch completes.
    pub fn score(&self, features: Vec<f32>, tenant: &str) -> Result<(f64, f64)> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.queue_tx
            .send(Pending {
                features,
                tenant: tenant.to_string(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("batcher has shut down"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("batcher dropped the reply"))?
    }

    /// Stop the worker without consuming the batcher (decommission
    /// path): late submitters — e.g. requests still holding a stale
    /// engine snapshot — get a clean "shut down" error instead of
    /// keeping a worker thread alive behind a retired snapshot.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake the worker if it is blocked waiting for a first event:
        // a sentinel whose reply channel is already closed.
        let (reply_tx, _) = mpsc::sync_channel(1);
        let _ = self.queue_tx.send(Pending {
            features: vec![],
            tenant: String::new(),
            reply: reply_tx,
        });
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Unblock the worker's recv with a sentinel-free approach:
        // dropping the sender closes the channel.
        let (dead_tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.queue_tx, dead_tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batcher_main(
    predictor: Arc<Predictor>,
    rx: mpsc::Receiver<Pending>,
    shutdown: Arc<AtomicBool>,
    max_batch: usize,
    max_delay: Duration,
    stats: Arc<Mutex<BatcherStats>>,
) {
    let d = predictor.feature_dim();
    // Reusable per-worker buffers: the feature matrix, the SoA expert
    // lanes and the raw-score vector persist across batches, so the
    // steady-state loop allocates nothing per batch.
    let mut features: Vec<f32> = Vec::new();
    let mut scratch = PipelineScratch::default();
    let mut raw: Vec<f64> = Vec::new();
    loop {
        // Block for the first event of a batch.
        let first = match rx.recv() {
            Ok(p) => p,
            Err(_) => return, // all senders gone
        };
        let deadline = Instant::now() + max_delay;
        let mut batch = vec![first];
        // Fill until the deadline or the batch limit.
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(p) => batch.push(p),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        if shutdown.load(Ordering::SeqCst) {
            for p in batch {
                let _ = p.reply.send(Err(anyhow!("batcher shutting down")));
            }
            return;
        }
        // Group by tenant (T^Q is tenant-specific) while keeping one
        // inference call for the whole batch: run raw once, then apply
        // each tenant's compiled pipeline tail.
        let n = batch.len();
        features.clear();
        features.reserve(n * d);
        let mut ok = true;
        for p in &batch {
            if p.features.len() != d {
                ok = false;
            }
            features.extend_from_slice(&p.features);
        }
        if !ok {
            for p in batch {
                let msg = if p.features.len() != d {
                    Err(anyhow!("bad feature dim"))
                } else {
                    Err(anyhow!("batch rejected (peer had bad feature dim)"))
                };
                let _ = p.reply.send(msg);
            }
            continue;
        }
        match predictor.score_batch_raw_compiled(&features, n, &mut scratch, &mut raw) {
            Ok(()) => {
                {
                    let mut s = stats.lock().unwrap();
                    s.batches += 1;
                    s.events += n as u64;
                }
                // One inference call for the mixed-tenant batch, then
                // each event gets its own tenant's T^Q (Section 2.3.3:
                // the mapping is tenant-specific). The compiled
                // quantile table is one snapshot load per batch, and
                // the tenant pipelines are resolved once per distinct
                // tenant in the batch (linear scan over the handful of
                // live groups) — zero per-event hashmap probes.
                let quantiles = predictor.quantile_table();
                let mut tenants: Vec<&str> = Vec::new();
                let mut pipes: Vec<&Arc<CompiledPipeline>> = Vec::new();
                for (p, &r) in batch.iter().zip(&raw) {
                    let g = match tenants.iter().position(|t| *t == p.tenant) {
                        Some(g) => g,
                        None => {
                            tenants.push(&p.tenant);
                            pipes.push(quantiles.pipeline_for(&p.tenant));
                            tenants.len() - 1
                        }
                    };
                    let _ = p.reply.send(Ok((pipes[g].finalize_one(r), r)));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for p in batch {
                    let _ = p.reply.send(Err(anyhow!(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MuseConfig, QuantileMode, PredictorConfig};
    use crate::coordinator::registry::PredictorRegistry;
    use crate::runtime::{Manifest, ModelPool};
    use crate::transforms::QuantileMap;
    use std::path::PathBuf;

    fn predictor() -> Option<Arc<Predictor>> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let reg = PredictorRegistry::new(Arc::new(ModelPool::new(
            Manifest::load(root).unwrap(),
        )));
        reg.deploy(
            &PredictorConfig {
                name: "p".into(),
                experts: vec!["m1".into(), "m2".into()],
                weights: vec![1.0, 1.0],
                quantile_mode: QuantileMode::Identity,
                reference: "fraud-default".into(),
                posterior_correction: true,
            },
            QuantileMap::identity(33).unwrap().shared(),
        )
        .unwrap();
        let _ = MuseConfig::default();
        reg.get("p").map(|p| {
            // Leak the registry so containers outlive this scope.
            std::mem::forget(reg);
            p
        })
    }

    #[test]
    fn concurrent_requests_are_coalesced() {
        let Some(p) = predictor() else { return };
        let d = p.feature_dim();
        let b = Arc::new(Batcher::new(
            Arc::clone(&p),
            64,
            Duration::from_millis(5),
        ));
        let handles: Vec<_> = (0..32)
            .map(|i| {
                let b = Arc::clone(&b);
                thread::spawn(move || {
                    let feats = vec![0.01 * i as f32; d];
                    b.score(feats, "t").unwrap()
                })
            })
            .collect();
        for h in handles {
            let (fin, raw) = h.join().unwrap();
            assert!((0.0..=1.0).contains(&fin));
            assert!((fin - raw).abs() < 1e-9); // identity T^Q
        }
        let s = b.stats();
        assert_eq!(s.events, 32);
        assert!(
            s.batches < 32,
            "expected coalescing, got {} batches for {} events",
            s.batches,
            s.events
        );
    }

    #[test]
    fn batched_results_match_direct_scoring() {
        let Some(p) = predictor() else { return };
        let d = p.feature_dim();
        let b = Batcher::new(Arc::clone(&p), 16, Duration::from_millis(1));
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..10 {
            let feats: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let (fin, raw) = b.score(feats.clone(), "t").unwrap();
            let direct = p.score(&feats, 1, "t").unwrap();
            assert!((fin - direct.scores[0]).abs() < 1e-9);
            assert!((raw - direct.raw[0]).abs() < 1e-9);
        }
    }

    #[test]
    fn per_tenant_transform_in_mixed_batch() {
        let Some(p) = predictor() else { return };
        let d = p.feature_dim();
        p.install_tenant_quantile(
            "vip",
            QuantileMap::new(vec![0.0, 1.0], vec![0.9, 1.0]).unwrap().shared(),
        );
        let b = Arc::new(Batcher::new(Arc::clone(&p), 8, Duration::from_millis(20)));
        let b1 = Arc::clone(&b);
        let h1 = thread::spawn(move || b1.score(vec![0.0; d], "vip").unwrap());
        let b2 = Arc::clone(&b);
        let h2 = thread::spawn(move || b2.score(vec![0.0; d], "normal").unwrap());
        let (vip, _) = h1.join().unwrap();
        let (normal, _) = h2.join().unwrap();
        assert!(vip >= 0.9, "vip transform not applied: {vip}");
        assert!(normal < 0.9, "normal tenant got vip transform: {normal}");
    }

    #[test]
    fn bad_feature_dim_is_rejected() {
        let Some(p) = predictor() else { return };
        let b = Batcher::new(Arc::clone(&p), 4, Duration::from_millis(1));
        assert!(b.score(vec![0.0; 3], "t").is_err());
    }

    #[test]
    fn shutdown_rejects_late_submitters() {
        let Some(p) = predictor() else { return };
        let d = p.feature_dim();
        let b = Batcher::new(Arc::clone(&p), 4, Duration::from_millis(1));
        b.score(vec![0.0; d], "t").unwrap();
        b.shutdown();
        // The worker exits; a stale-snapshot caller gets an error,
        // never a hang. (Exact message depends on where the race
        // lands: rejected at send, at batch time, or reply dropped.)
        let err = b.score(vec![0.0; d], "t").unwrap_err();
        assert!(err.to_string().contains("batcher"), "{err}");
    }

    #[test]
    fn max_delay_bounds_queueing() {
        let Some(p) = predictor() else { return };
        let d = p.feature_dim();
        let b = Batcher::new(Arc::clone(&p), 1024, Duration::from_millis(10));
        // A single request must not wait for a full batch: total time
        // stays near max_delay + inference, far under a second.
        let t0 = Instant::now();
        b.score(vec![0.0; d], "t").unwrap();
        assert!(t0.elapsed() < Duration::from_millis(500));
    }
}
