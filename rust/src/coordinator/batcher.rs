//! Dynamic batching: concurrent single-event requests to the same
//! predictor are coalesced into one batched inference call (amortising
//! the PJRT dispatch overhead), bounded by a max batch size and a max
//! queueing delay so tail latency stays inside the SLO.
//!
//! The paper's serving layer gets its throughput from Triton-side
//! batching; here the coordinator owns it, which also exercises the
//! AOT batch variants (1/16/64/256) produced by the compile path.
//!
//! # Allocation-free submit path
//!
//! The previous submit path paid, per event: a `sync_channel(1)`
//! allocation for the reply, a `String` clone of the tenant, a channel
//! node allocation for the queue send, and a mutex acquisition for the
//! stats. This one pays none of them:
//!
//! * the caller pins a `Submission` on its own stack — features are
//!   **borrowed** (`&[f32]`), valid because the caller blocks until
//!   the worker publishes the reply, and the tenant is a `Copy`
//!   [`TenantHandle`] interned at the ingress edge (see
//!   `coordinator::tenants`) — no string crosses the queue at all;
//! * the submission is linked into an intrusive Vyukov-style MPSC
//!   queue: a push is one `swap` + one `store`, wait-free, no heap
//!   node;
//! * the reply handshake is a per-submission atomic state flag plus
//!   `std::thread::park`/`unpark` — no channel;
//! * [`BatcherStats`] are plain atomics.
//!
//! ## Safety contract (the whole module hangs on it)
//!
//! A queued submission's memory — the stack frame of a caller inside
//! [`Batcher::score`] — stays valid until the worker stores
//! `DONE` into its state flag, because the caller does not return
//! before observing `DONE`. The worker therefore (a) never touches a
//! submission after flagging it, and (b) is guaranteed to flag every
//! submission exactly once, including on shutdown and on a panicking
//! scoring pass (a catch-unwind converts panics into error replies,
//! and a drop guard flags queue stragglers even if the worker thread
//! itself dies). The shutdown handshake closes the submit/teardown
//! race with an in-flight counter: submitters register *before*
//! checking the shutdown flag, and the worker keeps draining the queue
//! until the in-flight count reaches zero, so a submission enqueued
//! concurrently with shutdown is always flagged — a late submitter
//! gets a clean "shut down" error, never a hang (the contract the
//! decommission path relies on; there is no sentinel message and no
//! dead-channel trick anymore).
//!
//! Transform execution inside the worker is the **compiled pipeline**
//! (`transforms::pipeline`): expert scores land in a reusable SoA
//! scratch, the branch-free kernel aggregates them, and each tenant's
//! `T^Q` tail is resolved once per (batch, tenant) group — the staged
//! per-event path survives only as the reference oracle
//! (`Predictor::score_raw`).

use super::predictor::Predictor;
use super::tenants::TenantHandle;
use crate::transforms::{CompiledPipeline, PipelineScratch};
use anyhow::{anyhow, Result};
use std::cell::UnsafeCell;
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::{self, Thread};
use std::time::{Duration, Instant};

/// Reply states of one submission.
const PENDING: u32 = 0;
const DONE: u32 = 1;

/// One in-flight scoring request, pinned on the submitter's stack.
/// Fields are written by the submitter before the push and read by the
/// worker until it flags `state = DONE`; `result` crosses back the
/// other way. See the module-level safety contract.
struct Submission {
    /// Intrusive queue link (Vyukov MPSC).
    next: AtomicPtr<Submission>,
    /// Borrowed feature slice (valid until `state == DONE`).
    features: *const f32,
    features_len: usize,
    /// Interned tenant handle — `Copy`, so nothing is borrowed and no
    /// string is hashed anywhere past this point.
    tenant: TenantHandle,
    /// The submitting thread, unparked after the reply is published.
    waiter: Thread,
    state: AtomicU32,
    /// Written by the worker before `state = DONE` (Release), read by
    /// the submitter after observing `DONE` (Acquire).
    result: UnsafeCell<Option<Result<(f64, f64)>>>,
}

impl Submission {
    fn new(features: &[f32], tenant: TenantHandle) -> Submission {
        Submission {
            next: AtomicPtr::new(ptr::null_mut()),
            features: features.as_ptr(),
            features_len: features.len(),
            tenant,
            waiter: thread::current(),
            state: AtomicU32::new(PENDING),
            result: UnsafeCell::new(None),
        }
    }

    /// Queue stub node (never scored, never flagged).
    fn stub() -> Submission {
        Submission::new(&[], TenantHandle::INVALID)
    }

    /// The borrowed feature slice.
    ///
    /// SAFETY (caller): only before this submission is flagged `DONE`.
    unsafe fn features(&self) -> &[f32] {
        std::slice::from_raw_parts(self.features, self.features_len)
    }
}

/// Intrusive MPSC queue (Vyukov): producers push with one `swap` + one
/// `store`; the single consumer pops in FIFO order. Nodes are the
/// submissions themselves — no allocation anywhere.
struct SubmitQueue {
    /// Push end (most recently pushed node).
    head: AtomicPtr<Submission>,
    /// Pop end; consumer-owned (single consumer).
    tail: UnsafeCell<*mut Submission>,
    stub: Box<Submission>,
}

// SAFETY: `head` is an atomic; `tail` is only touched by the single
// consumer (the worker thread — enforced by this module, which never
// hands `pop` to anyone else); `stub` is only linked/unlinked through
// the queue protocol.
unsafe impl Send for SubmitQueue {}
unsafe impl Sync for SubmitQueue {}

impl SubmitQueue {
    fn new() -> SubmitQueue {
        let stub = Box::new(Submission::stub());
        let stub_ptr = &*stub as *const Submission as *mut Submission;
        SubmitQueue {
            head: AtomicPtr::new(stub_ptr),
            tail: UnsafeCell::new(stub_ptr),
            stub,
        }
    }

    fn stub_ptr(&self) -> *mut Submission {
        &*self.stub as *const Submission as *mut Submission
    }

    /// Producer side: wait-free (one swap, one store), no allocation.
    ///
    /// SAFETY (caller): `node` must stay valid until the consumer
    /// flags it `DONE` (stack pinning + park contract above).
    unsafe fn push(&self, node: *mut Submission) {
        (*node).next.store(ptr::null_mut(), Ordering::Relaxed);
        let prev = self.head.swap(node, Ordering::AcqRel);
        (*prev).next.store(node, Ordering::Release);
    }

    /// Consumer side (worker thread only). Returns the oldest
    /// submission, or `None` when the queue is empty *or* a producer
    /// is mid-push (retry shortly).
    ///
    /// SAFETY (caller): single consumer; returned nodes are owned by
    /// the caller until flagged.
    unsafe fn pop(&self) -> Option<*mut Submission> {
        let tail_cell = self.tail.get();
        let mut tail = *tail_cell;
        let mut next = (*tail).next.load(Ordering::Acquire);
        if tail == self.stub_ptr() {
            let n = next;
            if n.is_null() {
                return None; // empty
            }
            *tail_cell = n;
            tail = n;
            next = (*tail).next.load(Ordering::Acquire);
        }
        if !next.is_null() {
            *tail_cell = next;
            return Some(tail);
        }
        let head = self.head.load(Ordering::Acquire);
        if tail != head {
            return None; // producer between swap and store; retry
        }
        // Single element left: re-link the stub behind it so the
        // element can be detached.
        self.push(self.stub_ptr());
        next = (*tail).next.load(Ordering::Acquire);
        if !next.is_null() {
            *tail_cell = next;
            return Some(tail);
        }
        None
    }
}

/// State shared between submitters and the worker.
struct Shared {
    queue: SubmitQueue,
    shutdown: AtomicBool,
    /// Submitters inside `score` (registered *before* the shutdown
    /// check — the Dekker half that makes teardown race-free).
    inflight: AtomicUsize,
    /// Submissions pushed but not yet popped by the worker: the queue
    /// depth the ingress admission controller probes. Incremented
    /// before the push, decremented at every pop site, so a reader
    /// may transiently over-count but never under-count pressure.
    queued: AtomicUsize,
    batches: AtomicU64,
    events: AtomicU64,
}

/// Rolling batcher statistics.
#[derive(Debug, Default, Clone, Copy)]
pub struct BatcherStats {
    pub batches: u64,
    pub events: u64,
}

/// A dynamic batcher bound to one predictor.
pub struct Batcher {
    shared: Arc<Shared>,
    /// The worker's thread handle, for wakeups after a push.
    worker_thread: Thread,
    worker: Option<thread::JoinHandle<()>>,
    pub max_batch: usize,
    pub max_delay: Duration,
}

impl Batcher {
    pub fn new(predictor: Arc<Predictor>, max_batch: usize, max_delay: Duration) -> Batcher {
        assert!(max_batch >= 1);
        let shared = Arc::new(Shared {
            queue: SubmitQueue::new(),
            shutdown: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            queued: AtomicUsize::new(0),
            batches: AtomicU64::new(0),
            events: AtomicU64::new(0),
        });
        let shared_w = Arc::clone(&shared);
        let worker = thread::Builder::new()
            .name(format!("batcher-{}", predictor.name))
            .spawn(move || batcher_main(predictor, shared_w, max_batch, max_delay))
            .expect("spawn batcher");
        let worker_thread = worker.thread().clone();
        Batcher {
            shared,
            worker_thread,
            worker: Some(worker),
            max_batch,
            max_delay,
        }
    }

    /// Batching effectiveness so far (batches vs events coalesced).
    pub fn stats(&self) -> BatcherStats {
        BatcherStats {
            batches: self.shared.batches.load(Ordering::Relaxed),
            events: self.shared.events.load(Ordering::Relaxed),
        }
    }

    /// Submit one event; blocks until its batch completes. The
    /// features are borrowed for the duration of the call and the
    /// tenant is a `Copy` handle (interned once at the ingress edge) —
    /// the submit path performs **zero** heap allocations, **zero**
    /// string hashes and **zero** lock acquisitions (one queue swap,
    /// one state-flag wait).
    pub fn score(&self, features: &[f32], tenant: TenantHandle) -> Result<(f64, f64)> {
        // Register before the shutdown check (Dekker with the worker's
        // drain loop): either we observe shutdown here, or the worker
        // observes inflight > 0 and keeps draining until we are
        // flagged.
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        if self.shared.shutdown.load(Ordering::SeqCst) {
            self.shared.inflight.fetch_sub(1, Ordering::SeqCst);
            return Err(anyhow!("batcher has shut down"));
        }
        let sub = Submission::new(features, tenant);
        let sub_ptr = &sub as *const Submission as *mut Submission;
        self.shared.queued.fetch_add(1, Ordering::Relaxed);
        // SAFETY: `sub` lives on this stack frame and we do not return
        // before observing DONE below, which is the worker's last
        // access — the queue contract of the module docs.
        unsafe { self.shared.queue.push(sub_ptr) };
        // Unpark is cheap when the worker is running (token store) and
        // necessary when it parked waiting for a first event.
        self.worker_thread.unpark();
        while sub.state.load(Ordering::Acquire) != DONE {
            thread::park();
        }
        let result = unsafe { (*sub.result.get()).take() };
        self.shared.inflight.fetch_sub(1, Ordering::SeqCst);
        result.unwrap_or_else(|| Err(anyhow!("batcher dropped the reply")))
    }

    /// Submissions waiting in the queue right now (pushed, not yet
    /// popped by the worker). The ingress plane's admission
    /// controller reads this to decide tenant-priority shedding;
    /// wait-free, may transiently over-count, never under-counts.
    pub fn depth(&self) -> usize {
        self.shared.queued.load(Ordering::Relaxed)
    }

    /// Stop the worker without consuming the batcher (decommission
    /// path): late submitters — e.g. requests still holding a stale
    /// engine snapshot — get a clean "shut down" error instead of
    /// keeping a worker thread alive behind a retired snapshot.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.worker_thread.unpark();
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Flags one submission with a result and wakes its submitter. The
/// state store is the worker's final access to the submission; the
/// waiter handle is cloned out first.
///
/// SAFETY (caller): must be the queue consumer, flagging each popped
/// submission exactly once.
unsafe fn reply(sub: *mut Submission, result: Result<(f64, f64)>) {
    let waiter = (*sub).waiter.clone();
    *(*sub).result.get() = Some(result);
    (*sub).state.store(DONE, Ordering::Release);
    // `sub` may be invalid from here on — the submitter can wake and
    // return as soon as the store lands.
    waiter.unpark();
}

/// Worker-exit guard: even if the worker dies on a path that misses
/// the orderly drain (a panic outside the catch window), late and
/// queued submitters must be flagged, never left parked.
struct DrainOnExit {
    shared: Arc<Shared>,
}

impl Drop for DrainOnExit {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Keep draining until no submitter is registered. Submitters
        // registered after the shutdown store bail out before pushing.
        loop {
            // SAFETY: the worker thread is the sole consumer, and it
            // is exiting through this guard.
            while let Some(sub) = unsafe { self.shared.queue.pop() } {
                self.shared.queued.fetch_sub(1, Ordering::Relaxed);
                unsafe { reply(sub, Err(anyhow!("batcher shutting down"))) };
            }
            if self.shared.inflight.load(Ordering::SeqCst) == 0 {
                return;
            }
            thread::park_timeout(Duration::from_micros(50));
        }
    }
}

/// The worker's reusable buffers: persist across batches so the
/// steady-state loop allocates nothing per batch.
#[derive(Default)]
struct WorkerBufs {
    features: Vec<f32>,
    scratch: PipelineScratch,
    raw: Vec<f64>,
    /// Per-event final results, staged before any reply goes out.
    finals: Vec<Result<(f64, f64)>>,
}

fn batcher_main(
    predictor: Arc<Predictor>,
    shared: Arc<Shared>,
    max_batch: usize,
    max_delay: Duration,
) {
    let _guard = DrainOnExit {
        shared: Arc::clone(&shared),
    };
    let d = predictor.feature_dim();
    // Reusable per-worker buffers: the submission batch, the feature
    // matrix, the SoA expert lanes and the raw-score vector persist
    // across batches, so the steady-state loop allocates nothing per
    // batch.
    let mut batch: Vec<*mut Submission> = Vec::with_capacity(max_batch);
    let mut bufs = WorkerBufs::default();
    loop {
        // Block for the first event of a batch. A plain park suffices
        // (no poll timeout): every producer push and every shutdown is
        // followed by an unpark, and an unpark arriving between the
        // pop and the park leaves a token that makes the park return
        // immediately — no lost wakeup, no idle polling.
        let first = loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                return; // the exit guard drains stragglers
            }
            // SAFETY: single consumer (this thread).
            match unsafe { shared.queue.pop() } {
                Some(sub) => {
                    shared.queued.fetch_sub(1, Ordering::Relaxed);
                    break sub;
                }
                None => thread::park(),
            }
        };
        batch.clear();
        batch.push(first);
        // Fill until the deadline or the batch limit.
        let deadline = Instant::now() + max_delay;
        while batch.len() < max_batch {
            // SAFETY: single consumer (this thread).
            match unsafe { shared.queue.pop() } {
                Some(sub) => {
                    shared.queued.fetch_sub(1, Ordering::Relaxed);
                    batch.push(sub);
                }
                None => {
                    let now = Instant::now();
                    if now >= deadline || shared.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    thread::park_timeout((deadline - now).min(Duration::from_micros(50)));
                }
            }
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            for &sub in &batch {
                // SAFETY: popped by this consumer, flagged once.
                unsafe { reply(sub, Err(anyhow!("batcher shutting down"))) };
            }
            return;
        }
        // A panicking scoring pass must not strand parked submitters
        // or kill the worker: convert the panic into error replies.
        // `replied` tracks how many submissions were already flagged,
        // so the recovery path never double-flags one (a flagged
        // submitter may have returned and invalidated its frame).
        let mut replied = 0usize;
        let scored = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            process_batch(&predictor, &shared, &batch, d, &mut bufs, &mut replied)
        }));
        if scored.is_err() {
            for &sub in &batch[replied..] {
                // SAFETY: popped by this consumer, not yet flagged.
                unsafe { reply(sub, Err(anyhow!("batcher worker panicked during scoring"))) };
            }
        }
    }
}

/// Score one collected batch and reply to every submission. All
/// fallible/panicking work (inference, pipeline resolution,
/// finalization) is staged into `bufs.finals` first; the reply loops
/// run afterwards and only perform non-panicking operations, with
/// `replied` advanced per flag so the caller's panic recovery knows
/// exactly which submissions are still unflagged.
fn process_batch(
    predictor: &Arc<Predictor>,
    shared: &Shared,
    batch: &[*mut Submission],
    d: usize,
    bufs: &mut WorkerBufs,
    replied: &mut usize,
) {
    let n = batch.len();
    bufs.features.clear();
    bufs.features.reserve(n * d);
    let mut ok = true;
    for &sub in batch {
        // SAFETY: not yet flagged; borrow valid (module contract).
        let f = unsafe { (*sub).features() };
        if f.len() != d {
            ok = false;
        }
        bufs.features.extend_from_slice(f);
    }
    bufs.finals.clear();
    if ok {
        let scored =
            predictor.score_batch_raw_compiled(&bufs.features, n, &mut bufs.scratch, &mut bufs.raw);
        match scored {
            Ok(()) => {
                shared.batches.fetch_add(1, Ordering::Relaxed);
                shared.events.fetch_add(n as u64, Ordering::Relaxed);
                // One inference call for the mixed-tenant batch, then
                // each event gets its own tenant's T^Q (Section 2.3.3:
                // the mapping is tenant-specific). The compiled
                // quantile table is one snapshot load per batch, and
                // the tenant pipelines are resolved once per distinct
                // tenant in the batch (linear scan over the handful of
                // live groups) — zero per-event hashmap probes.
                let quantiles = predictor.quantile_table();
                let mut tenants: Vec<TenantHandle> = Vec::new();
                let mut pipes: Vec<Arc<CompiledPipeline>> = Vec::new();
                for (&sub, &r) in batch.iter().zip(bufs.raw.iter()) {
                    // SAFETY: not yet flagged (Copy read of the handle).
                    let tenant = unsafe { (*sub).tenant };
                    // Integer compares over the handful of live groups;
                    // pipeline resolution itself is an array index.
                    let g = match tenants.iter().position(|t| *t == tenant) {
                        Some(g) => g,
                        None => {
                            tenants.push(tenant);
                            pipes.push(quantiles.pipeline_for_handle(tenant));
                            tenants.len() - 1
                        }
                    };
                    bufs.finals.push(Ok((pipes[g].finalize_one(r), r)));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for _ in 0..n {
                    bufs.finals.push(Err(anyhow!(msg.clone())));
                }
            }
        }
    } else {
        for &sub in batch {
            // SAFETY: not yet flagged; borrow valid.
            let bad = unsafe { (*sub).features().len() } != d;
            bufs.finals.push(if bad {
                Err(anyhow!("bad feature dim"))
            } else {
                Err(anyhow!("batch rejected (peer had bad feature dim)"))
            });
        }
    }
    debug_assert_eq!(bufs.finals.len(), n);
    // Reply phase: nothing here panics (moves, atomic stores, unpark).
    for (&sub, result) in batch.iter().zip(bufs.finals.drain(..)) {
        // SAFETY: popped by the consumer, flagged exactly once; the
        // flag is the worker's last access to `sub`.
        unsafe { reply(sub, result) };
        *replied += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MuseConfig, PredictorConfig, QuantileMode};
    use crate::coordinator::registry::PredictorRegistry;
    use crate::runtime::{Manifest, ModelPool};
    use crate::transforms::QuantileMap;
    use std::path::PathBuf;

    fn predictor() -> Option<Arc<Predictor>> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let reg = PredictorRegistry::new(Arc::new(ModelPool::new(
            Manifest::load(root).unwrap(),
        )));
        reg.deploy(
            &PredictorConfig {
                name: "p".into(),
                experts: vec!["m1".into(), "m2".into()],
                weights: vec![1.0, 1.0],
                quantile_mode: QuantileMode::Identity,
                reference: "fraud-default".into(),
                posterior_correction: true,
            },
            QuantileMap::identity(33).unwrap().shared(),
        )
        .unwrap();
        let _ = MuseConfig::default();
        reg.get("p").map(|p| {
            // Leak the registry so containers outlive this scope.
            std::mem::forget(reg);
            p
        })
    }

    #[test]
    fn concurrent_requests_are_coalesced() {
        let Some(p) = predictor() else { return };
        let d = p.feature_dim();
        let b = Arc::new(Batcher::new(
            Arc::clone(&p),
            64,
            Duration::from_millis(5),
        ));
        let t = p.tenants().resolve("t");
        let handles: Vec<_> = (0..32)
            .map(|i| {
                let b = Arc::clone(&b);
                thread::spawn(move || {
                    let feats = vec![0.01 * i as f32; d];
                    b.score(&feats, t).unwrap()
                })
            })
            .collect();
        for h in handles {
            let (fin, raw) = h.join().unwrap();
            assert!((0.0..=1.0).contains(&fin));
            assert!((fin - raw).abs() < 1e-9); // identity T^Q
        }
        let s = b.stats();
        assert_eq!(s.events, 32);
        assert!(
            s.batches < 32,
            "expected coalescing, got {} batches for {} events",
            s.batches,
            s.events
        );
    }

    #[test]
    fn batched_results_match_direct_scoring() {
        let Some(p) = predictor() else { return };
        let d = p.feature_dim();
        let b = Batcher::new(Arc::clone(&p), 16, Duration::from_millis(1));
        let t = p.tenants().resolve("t");
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..10 {
            let feats: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let (fin, raw) = b.score(&feats, t).unwrap();
            let direct = p.score(&feats, 1, "t").unwrap();
            assert!((fin - direct.scores[0]).abs() < 1e-9);
            assert!((raw - direct.raw[0]).abs() < 1e-9);
        }
    }

    #[test]
    fn per_tenant_transform_in_mixed_batch() {
        let Some(p) = predictor() else { return };
        let d = p.feature_dim();
        p.install_tenant_quantile(
            "vip",
            QuantileMap::new(vec![0.0, 1.0], vec![0.9, 1.0]).unwrap().shared(),
        );
        let b = Arc::new(Batcher::new(Arc::clone(&p), 8, Duration::from_millis(20)));
        let vip_h = p.tenants().resolve("vip");
        let normal_h = p.tenants().resolve("normal");
        let b1 = Arc::clone(&b);
        let h1 = thread::spawn(move || b1.score(&vec![0.0; d], vip_h).unwrap());
        let b2 = Arc::clone(&b);
        let h2 = thread::spawn(move || b2.score(&vec![0.0; d], normal_h).unwrap());
        let (vip, _) = h1.join().unwrap();
        let (normal, _) = h2.join().unwrap();
        assert!(vip >= 0.9, "vip transform not applied: {vip}");
        assert!(normal < 0.9, "normal tenant got vip transform: {normal}");
    }

    #[test]
    fn bad_feature_dim_is_rejected() {
        let Some(p) = predictor() else { return };
        let b = Batcher::new(Arc::clone(&p), 4, Duration::from_millis(1));
        assert!(b.score(&[0.0; 3], p.tenants().resolve("t")).is_err());
    }

    #[test]
    fn shutdown_rejects_late_submitters() {
        let Some(p) = predictor() else { return };
        let d = p.feature_dim();
        let b = Batcher::new(Arc::clone(&p), 4, Duration::from_millis(1));
        let t = p.tenants().resolve("t");
        b.score(&vec![0.0; d], t).unwrap();
        b.shutdown();
        // The worker exits; a stale-snapshot caller gets an error,
        // never a hang. (Exact message depends on where the race
        // lands: rejected at submit or flagged by the drain.)
        let err = b.score(&vec![0.0; d], t).unwrap_err();
        assert!(err.to_string().contains("batcher"), "{err}");
    }

    #[test]
    fn shutdown_flags_queued_submitters() {
        // Submissions racing a shutdown must all resolve (reply or
        // clean error) — the in-flight handshake, hammered.
        let Some(p) = predictor() else { return };
        let d = p.feature_dim();
        let t = p.tenants().resolve("t");
        for round in 0..8 {
            let b = Arc::new(Batcher::new(
                Arc::clone(&p),
                64,
                Duration::from_millis(2),
            ));
            let handles: Vec<_> = (0..8)
                .map(|i| {
                    let b = Arc::clone(&b);
                    thread::spawn(move || {
                        let feats = vec![0.01 * i as f32; d];
                        // Result may be Ok or a shutdown error; it
                        // must never hang.
                        let _ = b.score(&feats, t);
                    })
                })
                .collect();
            if round % 2 == 0 {
                thread::yield_now();
            }
            b.shutdown();
            for h in handles {
                h.join().unwrap();
            }
        }
    }

    #[test]
    fn max_delay_bounds_queueing() {
        let Some(p) = predictor() else { return };
        let d = p.feature_dim();
        let b = Batcher::new(Arc::clone(&p), 1024, Duration::from_millis(10));
        // A single request must not wait for a full batch: total time
        // stays near max_delay + inference, far under a second.
        let t0 = Instant::now();
        b.score(&vec![0.0; d], p.tenants().resolve("t")).unwrap();
        assert!(t0.elapsed() < Duration::from_millis(500));
    }
}
