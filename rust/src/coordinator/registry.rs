//! The predictor registry: deploys/decommissions predictors against
//! the shared model-container pool, maintaining the predictor<->model
//! reference graph that realises the paper's infrastructure
//! deduplication (Section 2.2.1): "a single model deployment can be
//! referenced by hundreds of predictors".

use super::predictor::{ExpertSlot, Predictor};
use super::tenants::TenantInterner;
use crate::config::PredictorConfig;
use crate::runtime::{ModelPool, PoolStats};
use crate::transforms::{Aggregation, PosteriorCorrection, QuantileMap};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

pub struct PredictorRegistry {
    pool: Arc<ModelPool>,
    predictors: RwLock<HashMap<String, Arc<Predictor>>>,
    /// Deploy-time configs, kept so control loops (the lifecycle
    /// autopilot's shadow-candidate derivation) can re-deploy a
    /// predictor's expert/weight/reference tuple under a new name.
    configs: RwLock<HashMap<String, PredictorConfig>>,
    /// Bumped on every successful deploy/decommission; the engine's
    /// snapshot staleness gate compares it so registry mutations made
    /// without a routing swap still trigger a republish.
    generation: AtomicU64,
    /// The engine-wide tenant interner, handed to every deployed
    /// predictor so handle-indexed tables agree on the numbering.
    tenants: Arc<TenantInterner>,
}

/// Registry + pool occupancy, for the dedup accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct RegistryStats {
    pub predictors: usize,
    /// Sum over predictors of their expert counts ("logical models").
    pub model_references: usize,
    /// Live physical containers (deduplicated).
    pub pool: PoolStats,
}

impl PredictorRegistry {
    pub fn new(pool: Arc<ModelPool>) -> Self {
        Self::with_interner(pool, Arc::new(TenantInterner::new()))
    }

    /// Build a registry sharing an existing tenant interner — the
    /// engine passes its own so the admission controller, the routes
    /// and every predictor's quantile table use one numbering.
    pub fn with_interner(pool: Arc<ModelPool>, tenants: Arc<TenantInterner>) -> Self {
        PredictorRegistry {
            pool,
            predictors: RwLock::new(HashMap::new()),
            configs: RwLock::new(HashMap::new()),
            generation: AtomicU64::new(0),
            tenants,
        }
    }

    pub fn pool(&self) -> &Arc<ModelPool> {
        &self.pool
    }

    /// The tenant interner shared by every predictor in this registry.
    pub fn tenants(&self) -> &Arc<TenantInterner> {
        &self.tenants
    }

    /// Monotonic deployment-set version (see field docs).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    /// Deploy a predictor from config with an explicit initial `T^Q`.
    /// Acquires (or reuses) one container per expert; on any failure,
    /// already-acquired references are released (no leaks).
    pub fn deploy(&self, cfg: &PredictorConfig, quantile: Arc<QuantileMap>) -> Result<()> {
        if self.predictors.read().unwrap().contains_key(&cfg.name) {
            bail!("predictor '{}' is already deployed", cfg.name);
        }
        let mut experts = Vec::with_capacity(cfg.experts.len());
        let mut acquired: Vec<String> = vec![];
        let build = (|| -> Result<Vec<ExpertSlot>> {
            for model in &cfg.experts {
                let handle = self
                    .pool
                    .acquire(model)
                    .with_context(|| format!("deploy '{}': model '{model}'", cfg.name))?;
                acquired.push(model.clone());
                let correction = if cfg.posterior_correction {
                    Some(PosteriorCorrection::new(handle.beta)?)
                } else {
                    None
                };
                experts.push(ExpertSlot { handle, correction });
            }
            Ok(experts)
        })();
        let experts = match build {
            Ok(e) => e,
            Err(err) => {
                for m in &acquired {
                    self.pool.release(m);
                }
                return Err(err);
            }
        };
        let aggregation = if cfg.experts.len() == 1 {
            Aggregation::Identity
        } else {
            Aggregation::weighted(cfg.weights.clone())?
        };
        let predictor = match Predictor::new(
            cfg.name.clone(),
            experts,
            aggregation,
            quantile,
            Arc::clone(&self.tenants),
        ) {
            Ok(p) => p,
            Err(err) => {
                for m in &acquired {
                    self.pool.release(m);
                }
                return Err(err);
            }
        };
        self.predictors
            .write()
            .unwrap()
            .insert(cfg.name.clone(), Arc::new(predictor));
        self.configs
            .write()
            .unwrap()
            .insert(cfg.name.clone(), cfg.clone());
        self.generation.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// Decommission: remove the predictor and release its model
    /// references (containers with zero refs are torn down by the
    /// pool) — the final step of the Fig. 3 lifecycle.
    pub fn decommission(&self, name: &str) -> Result<()> {
        let removed = self.predictors.write().unwrap().remove(name);
        let Some(p) = removed else {
            bail!("predictor '{name}' is not deployed");
        };
        self.configs.write().unwrap().remove(name);
        self.generation.fetch_add(1, Ordering::SeqCst);
        for model in p.expert_names() {
            self.pool.release(&model);
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<Arc<Predictor>> {
        self.predictors.read().unwrap().get(name).cloned()
    }

    /// The config a predictor was deployed with (cloned).
    pub fn config(&self, name: &str) -> Option<PredictorConfig> {
        self.configs.read().unwrap().get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.predictors.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    pub fn stats(&self) -> RegistryStats {
        let preds = self.predictors.read().unwrap();
        RegistryStats {
            predictors: preds.len(),
            model_references: preds.values().map(|p| p.n_experts()).sum(),
            pool: self.pool.stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QuantileMode;
    use crate::runtime::Manifest;
    use std::path::PathBuf;

    fn registry() -> Option<PredictorRegistry> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(PredictorRegistry::new(Arc::new(ModelPool::new(
            Manifest::load(root).unwrap(),
        ))))
    }

    fn cfg(name: &str, experts: &[&str]) -> PredictorConfig {
        PredictorConfig {
            name: name.into(),
            experts: experts.iter().map(|s| s.to_string()).collect(),
            weights: vec![1.0; experts.len()],
            quantile_mode: QuantileMode::Identity,
            reference: "fraud-default".into(),
            posterior_correction: experts.len() > 1,
        }
    }

    fn identity() -> Arc<QuantileMap> {
        QuantileMap::identity(33).unwrap().shared()
    }

    #[test]
    fn fig1_deployment_dedup() {
        let Some(reg) = registry() else { return };
        // p1 = {m1, m2}: two containers.
        reg.deploy(&cfg("p1", &["m1", "m2"]), identity()).unwrap();
        let s1 = reg.stats();
        assert_eq!(s1.pool.live_containers, 2);
        assert_eq!(s1.model_references, 2);
        // p2 = {m1, m2, m3}: only m3 is net-new (the paper's claim).
        reg.deploy(&cfg("p2", &["m1", "m2", "m3"]), identity()).unwrap();
        let s2 = reg.stats();
        assert_eq!(s2.predictors, 2);
        assert_eq!(s2.model_references, 5);
        assert_eq!(s2.pool.live_containers, 3, "marginal cost = net difference");
        // Decommission p1 (lifecycle Fig. 3): m1, m2 stay alive for p2.
        reg.decommission("p1").unwrap();
        let s3 = reg.stats();
        assert_eq!(s3.predictors, 1);
        assert_eq!(s3.pool.live_containers, 3);
        // Decommission p2: everything torn down.
        reg.decommission("p2").unwrap();
        assert_eq!(reg.stats().pool.live_containers, 0);
    }

    #[test]
    fn duplicate_deploy_rejected() {
        let Some(reg) = registry() else { return };
        reg.deploy(&cfg("p", &["m1"]), identity()).unwrap();
        assert!(reg.deploy(&cfg("p", &["m2"]), identity()).is_err());
        // The failed deploy must not leak a container for m2.
        assert_eq!(reg.stats().pool.live_containers, 1);
    }

    #[test]
    fn failed_deploy_releases_acquired_models() {
        let Some(reg) = registry() else { return };
        // m1 is valid, m99 is not: the half-acquired m1 must be released.
        let bad = cfg("p", &["m1", "m99"]);
        assert!(reg.deploy(&bad, identity()).is_err());
        assert_eq!(reg.stats().pool.live_containers, 0);
    }

    #[test]
    fn decommission_unknown_is_error() {
        let Some(reg) = registry() else { return };
        assert!(reg.decommission("ghost").is_err());
    }

    #[test]
    fn get_and_score_through_registry() {
        let Some(reg) = registry() else { return };
        reg.deploy(&cfg("p", &["m1", "m2"]), identity()).unwrap();
        let p = reg.get("p").unwrap();
        let d = p.feature_dim();
        let out = p.score(&vec![0.0f32; 2 * d], 2, "t").unwrap();
        assert_eq!(out.scores.len(), 2);
        assert!(reg.get("nope").is_none());
        assert_eq!(reg.names(), vec!["p".to_string()]);
    }

    #[test]
    fn single_model_predictor_uses_identity_aggregation() {
        let Some(reg) = registry() else { return };
        // Paper: single-model predictors skip T^C and A is identity.
        let mut c = cfg("single", &["m1"]);
        c.posterior_correction = false;
        reg.deploy(&c, identity()).unwrap();
        let p = reg.get("single").unwrap();
        assert_eq!(p.n_experts(), 1);
    }
}
