//! Tenant-handle interning: resolve a tenant's name to a dense
//! integer **once, at the ingress edge**, and index every downstream
//! tenant-keyed structure by that integer instead of re-hashing the
//! string per event.
//!
//! Before this module, one scored event paid up to six separate
//! tenant-string hashes past routing: the batcher's per-group tenant
//! compare, the quantile table's `pipeline_for` probe, the data lake's
//! pair-slot probe, the lifecycle hub's feed-table probe, the
//! per-tenant event counter, and the admission controller's priority
//! scan. With interning, the engine resolves the tenant to a
//! [`TenantHandle`] (one hash) when the request enters, and every
//! later hop is an array index off that handle — see
//! `coordinator::snapshot::TenantRoute` for the per-predictor route
//! cache the handle keys.
//!
//! The table is published copy-on-write through a
//! [`SnapCell`](crate::util::swap::SnapCell): lookups are one
//! wait-free load + one map probe; interning a never-seen tenant takes
//! the cell's writer lock once per tenant *lifetime* (control-plane
//! rate). Handles are dense (`0..len`), never reused, and permanently
//! valid — downstream tables sized before a tenant appeared simply
//! don't cover its index yet, and treat the miss as "use defaults",
//! which is exactly the behavior a brand-new tenant should get.

use crate::util::swap::SnapCell;
use std::collections::HashMap;
use std::sync::Arc;

/// A dense, copyable tenant identifier. `Copy` on purpose: handles
/// cross thread boundaries (batcher submissions, shadow closures)
/// without cloning a `String` or pinning a borrow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TenantHandle(u32);

impl TenantHandle {
    /// A handle that is valid to *use* but matches no interned tenant:
    /// every handle-indexed table treats it as out of range and serves
    /// defaults. Used for queue stubs and other never-scored slots.
    pub const INVALID: TenantHandle = TenantHandle(u32::MAX);

    /// The dense index this handle occupies in handle-keyed tables.
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// Immutable interner snapshot: name → handle plus the reverse map.
#[derive(Default)]
struct TenantTable {
    by_name: HashMap<Arc<str>, u32>,
    names: Vec<Arc<str>>,
}

/// The process-wide tenant interner (one per engine, shared with the
/// admission controller). See the module docs for the contract.
pub struct TenantInterner {
    cell: SnapCell<TenantTable>,
}

impl Default for TenantInterner {
    fn default() -> Self {
        Self::new()
    }
}

impl TenantInterner {
    pub fn new() -> TenantInterner {
        TenantInterner {
            cell: SnapCell::new(Arc::new(TenantTable::default())),
        }
    }

    /// Resolve without interning: `None` for a never-seen tenant.
    /// The admission controller uses this so unauthenticated junk
    /// tenant names shed *without* growing the table.
    pub fn lookup(&self, tenant: &str) -> Option<TenantHandle> {
        self.cell.load().by_name.get(tenant).copied().map(TenantHandle)
    }

    /// Resolve, interning on first sight — the ingress edge's one
    /// tenant-string hash. Wait-free for every established tenant.
    pub fn resolve(&self, tenant: &str) -> TenantHandle {
        if let Some(h) = self.lookup(tenant) {
            return h;
        }
        self.intern(tenant)
    }

    #[cold]
    fn intern(&self, tenant: &str) -> TenantHandle {
        self.cell.rcu(|old| {
            // Re-probe under the writer lock: racing interners must
            // converge on one handle per name.
            if let Some(&h) = old.by_name.get(tenant) {
                return (Arc::clone(old), TenantHandle(h));
            }
            let id = u32::try_from(old.names.len()).expect("tenant handle overflow");
            let name: Arc<str> = Arc::from(tenant);
            let mut next = TenantTable {
                by_name: old.by_name.clone(),
                names: old.names.clone(),
            };
            next.names.push(Arc::clone(&name));
            next.by_name.insert(name, id);
            (Arc::new(next), TenantHandle(id))
        })
    }

    /// The interned name behind a handle (`None` for
    /// [`TenantHandle::INVALID`] or a foreign handle).
    pub fn name(&self, handle: TenantHandle) -> Option<Arc<str>> {
        self.cell.load().names.get(handle.index()).cloned()
    }

    /// Number of interned tenants (handles are dense: `0..len`).
    pub fn len(&self) -> usize {
        self.cell.load().names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_dense_and_stable() {
        let t = TenantInterner::new();
        let a = t.resolve("acme");
        let b = t.resolve("bank1");
        assert_eq!(a.index(), 0);
        assert_eq!(b.index(), 1);
        // Re-resolving is a pure lookup returning the same handle.
        assert_eq!(t.resolve("acme"), a);
        assert_eq!(t.lookup("acme"), Some(a));
        assert_eq!(t.len(), 2);
        assert_eq!(&*t.name(a).unwrap(), "acme");
        assert_eq!(&*t.name(b).unwrap(), "bank1");
    }

    #[test]
    fn lookup_never_interns() {
        let t = TenantInterner::new();
        assert_eq!(t.lookup("ghost"), None);
        assert_eq!(t.len(), 0, "lookup must not grow the table");
        assert_eq!(t.name(TenantHandle::INVALID), None);
    }

    #[test]
    fn concurrent_interning_converges_on_one_handle_per_name() {
        let t = Arc::new(TenantInterner::new());
        let handles: Vec<_> = (0..8)
            .map(|w| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    let mut seen = Vec::new();
                    for i in 0..64 {
                        // Shared names race; per-worker names interleave.
                        seen.push((format!("shared{}", i % 7), t.resolve(&format!("shared{}", i % 7))));
                        seen.push((format!("own{w}"), t.resolve(&format!("own{w}"))));
                    }
                    seen
                })
            })
            .collect();
        let mut by_name: HashMap<String, TenantHandle> = HashMap::new();
        for h in handles {
            for (name, handle) in h.join().unwrap() {
                let prev = by_name.entry(name.clone()).or_insert(handle);
                assert_eq!(*prev, handle, "name '{name}' got two handles");
            }
        }
        assert_eq!(t.len(), 7 + 8);
        // Dense: every index below len is named, round-trips by name.
        for i in 0..t.len() {
            let name = t.name(by_name.values().find(|h| h.index() == i).copied().unwrap());
            assert!(name.is_some());
        }
    }
}
