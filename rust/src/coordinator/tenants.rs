//! Tenant-handle interning: resolve a tenant's name to a dense
//! integer **once, at the ingress edge**, and index every downstream
//! tenant-keyed structure by that integer instead of re-hashing the
//! string per event.
//!
//! Before this module, one scored event paid up to six separate
//! tenant-string hashes past routing: the batcher's per-group tenant
//! compare, the quantile table's `pipeline_for` probe, the data lake's
//! pair-slot probe, the lifecycle hub's feed-table probe, the
//! per-tenant event counter, and the admission controller's priority
//! scan. With interning, the engine resolves the tenant to a
//! [`TenantHandle`] (one hash) when the request enters, and every
//! later hop is an array index off that handle — see
//! `coordinator::snapshot::TenantRoute` for the per-predictor route
//! cache the handle keys.
//!
//! # Scale-out layout (the 100k-tenant onboarding storm)
//!
//! The name → handle map is **sharded by name hash** across N
//! independent [`SnapCell`](crate::util::swap::SnapCell)s: lookups
//! stay one wait-free load + one map probe, but interning a
//! never-seen tenant republishes only its owning shard (O(tenants/N)
//! instead of O(tenants) per first touch, with N writer locks
//! admitting concurrent onboarding). The handle → name reverse map
//! is a [`HandleSlab`](crate::util::swap) — lazily allocated
//! fixed-size segments, so publishing a new name clones one
//! constant-size segment, never the table.
//!
//! Handles are allocated from one monotone counter: dense
//! (`0..len`), **never reused**, and permanently valid — downstream
//! tables sized before a tenant appeared simply don't cover its index
//! yet, and treat the miss as "use defaults", which is exactly the
//! behavior a brand-new tenant should get.
//!
//! # Epochs and decommission
//!
//! [`TenantInterner::retire`] removes a name from the forward map and
//! bumps the interner **epoch**. The handle stays allocated (its name
//! still reverse-resolves, its slab slots stay addressable for
//! drain-out), but a later [`resolve`](TenantInterner::resolve) of
//! the same name allocates a *fresh* handle — per-tenant state from
//! the previous tenancy can never be confused with the new one. The
//! epoch counter lets caches that key off handles observe that the
//! name ↔ handle binding moved.

use crate::util::slab::HandleSlab;
use crate::util::swap::SnapCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

/// Default shard count for the name → handle map. 16 shards keep the
/// worst-case first-touch republish at tenants/16 map clones while
/// letting 16 onboarding threads intern without serializing.
pub const DEFAULT_NAME_SHARDS: usize = 16;

/// A dense, copyable tenant identifier. `Copy` on purpose: handles
/// cross thread boundaries (batcher submissions, shadow closures)
/// without cloning a `String` or pinning a borrow. `Ord` so that
/// handle-keyed control-plane maps (lifecycle pair states) iterate
/// deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantHandle(u32);

impl TenantHandle {
    /// A handle that is valid to *use* but matches no interned tenant:
    /// every handle-indexed table treats it as out of range and serves
    /// defaults. Used for queue stubs and other never-scored slots.
    pub const INVALID: TenantHandle = TenantHandle(u32::MAX);

    /// The dense index this handle occupies in handle-keyed tables.
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// Rehydrate a handle from its dense index. For slab-iteration
    /// consumers (streaming `/metrics`, oracle diffs) reconstructing
    /// handles the slab yielded as indices; the data plane only ever
    /// receives handles from the interner.
    pub fn from_index(index: usize) -> TenantHandle {
        TenantHandle(u32::try_from(index).expect("tenant handle overflow"))
    }
}

/// FNV-1a over the name bytes — one cheap pass to pick the owning
/// shard (the shard map re-hashes internally for its probe).
#[inline]
fn shard_hash(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The process-wide tenant interner (one per engine, shared with the
/// admission controller). See the module docs for the contract.
pub struct TenantInterner {
    /// Name → handle, sharded by name hash; each shard publishes
    /// copy-on-write independently.
    shards: Box<[SnapCell<HashMap<Arc<str>, u32>>]>,
    /// Handle → name (slab-indexed; entries are permanent).
    names: HandleSlab<Arc<str>>,
    /// Next handle to allocate. Monotone: handles are never reused.
    next: AtomicU32,
    /// Bumped on every successful [`retire`](TenantInterner::retire).
    epoch: AtomicU64,
    /// Total retirements (observability / tsunami accounting).
    retired: AtomicU64,
}

impl Default for TenantInterner {
    fn default() -> Self {
        Self::new()
    }
}

impl TenantInterner {
    pub fn new() -> TenantInterner {
        TenantInterner::with_shards(DEFAULT_NAME_SHARDS)
    }

    /// An interner with an explicit shard count (1 reproduces the old
    /// single-cell COW layout — the equivalence tests pin that).
    pub fn with_shards(shards: usize) -> TenantInterner {
        let shards = shards.max(1);
        TenantInterner {
            shards: (0..shards)
                .map(|_| SnapCell::new(Arc::new(HashMap::new())))
                .collect(),
            names: HandleSlab::with_shards(shards),
            next: AtomicU32::new(0),
            epoch: AtomicU64::new(0),
            retired: AtomicU64::new(0),
        }
    }

    #[inline]
    fn shard(&self, tenant: &str) -> &SnapCell<HashMap<Arc<str>, u32>> {
        &self.shards[(shard_hash(tenant) as usize) % self.shards.len()]
    }

    /// Resolve without interning: `None` for a never-seen (or
    /// retired) tenant. The admission controller uses this so
    /// unauthenticated junk tenant names shed *without* growing the
    /// table.
    pub fn lookup(&self, tenant: &str) -> Option<TenantHandle> {
        self.shard(tenant).load().get(tenant).copied().map(TenantHandle)
    }

    /// Resolve, interning on first sight — the ingress edge's one
    /// tenant-string hash. Wait-free for every established tenant.
    pub fn resolve(&self, tenant: &str) -> TenantHandle {
        if let Some(h) = self.lookup(tenant) {
            return h;
        }
        self.intern(tenant)
    }

    #[cold]
    fn intern(&self, tenant: &str) -> TenantHandle {
        self.shard(tenant).rcu(|old| {
            // Re-probe under the shard's writer lock: racing interners
            // must converge on one handle per name.
            if let Some(&h) = old.get(tenant) {
                return (Arc::clone(old), TenantHandle(h));
            }
            let id = self.next.fetch_add(1, Ordering::Relaxed);
            assert!(id != u32::MAX, "tenant handle overflow");
            let name: Arc<str> = Arc::from(tenant);
            // Publish the reverse map first so the handle names
            // itself the instant the forward probe can return it.
            self.names.set(id as usize, Arc::clone(&name));
            let mut next = old.as_ref().clone();
            next.insert(name, id);
            (Arc::new(next), TenantHandle(id))
        })
    }

    /// Decommission a tenancy: unbind `tenant` from its handle and
    /// bump the interner epoch. The handle is **not** freed — it
    /// stays allocated and reverse-resolvable so in-flight work and
    /// slab-indexed state drain out addressably — but a subsequent
    /// `resolve` of the same name allocates a fresh handle. Returns
    /// the retired handle (`None`: name was not bound).
    pub fn retire(&self, tenant: &str) -> Option<TenantHandle> {
        let retired = self.shard(tenant).rcu(|old| match old.get(tenant) {
            None => (Arc::clone(old), None),
            Some(&h) => {
                let mut next = old.as_ref().clone();
                next.remove(tenant);
                (Arc::new(next), Some(TenantHandle(h)))
            }
        });
        if retired.is_some() {
            self.retired.fetch_add(1, Ordering::Relaxed);
            self.epoch.fetch_add(1, Ordering::Release);
        }
        retired
    }

    /// The current name ↔ handle binding epoch: bumps once per
    /// retirement. Caches keyed by handle use a stable epoch across
    /// two reads as their validity witness.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Total tenancies retired so far.
    pub fn retired_count(&self) -> u64 {
        self.retired.load(Ordering::Relaxed)
    }

    /// The interned name behind a handle (`None` for
    /// [`TenantHandle::INVALID`] or a foreign handle). Retired
    /// handles still name themselves — state keyed by them stays
    /// attributable.
    pub fn name(&self, handle: TenantHandle) -> Option<Arc<str>> {
        if handle == TenantHandle::INVALID {
            return None;
        }
        self.names.get(handle.index())
    }

    /// Number of handles ever allocated (handles are dense: `0..len`,
    /// retirements included).
    pub fn len(&self) -> usize {
        self.next.load(Ordering::Relaxed) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Reverse-map segments actually allocated (tsunami RSS
    /// accounting: growth must be O(tenants), in constant-size steps).
    pub fn name_segments(&self) -> usize {
        self.names.segments_allocated()
    }

    /// Shard count this interner was built with. Slab-backed tenant
    /// state planes (lifecycle feed table, counter slabs) size their
    /// own shards to match, so a handle's shard assignment is
    /// consistent across every registry it indexes.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn handles_are_dense_and_stable() {
        let t = TenantInterner::new();
        let a = t.resolve("acme");
        let b = t.resolve("bank1");
        assert_eq!(a.index(), 0);
        assert_eq!(b.index(), 1);
        // Re-resolving is a pure lookup returning the same handle.
        assert_eq!(t.resolve("acme"), a);
        assert_eq!(t.lookup("acme"), Some(a));
        assert_eq!(t.len(), 2);
        assert_eq!(&*t.name(a).unwrap(), "acme");
        assert_eq!(&*t.name(b).unwrap(), "bank1");
    }

    #[test]
    fn lookup_never_interns() {
        let t = TenantInterner::new();
        assert_eq!(t.lookup("ghost"), None);
        assert_eq!(t.len(), 0, "lookup must not grow the table");
        assert_eq!(t.name(TenantHandle::INVALID), None);
    }

    #[test]
    fn concurrent_interning_converges_on_one_handle_per_name() {
        let t = Arc::new(TenantInterner::new());
        let handles: Vec<_> = (0..8)
            .map(|w| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    let mut seen = Vec::new();
                    for i in 0..64 {
                        // Shared names race; per-worker names interleave.
                        seen.push((format!("shared{}", i % 7), t.resolve(&format!("shared{}", i % 7))));
                        seen.push((format!("own{w}"), t.resolve(&format!("own{w}"))));
                    }
                    seen
                })
            })
            .collect();
        let mut by_name: HashMap<String, TenantHandle> = HashMap::new();
        for h in handles {
            for (name, handle) in h.join().unwrap() {
                let prev = by_name.entry(name.clone()).or_insert(handle);
                assert_eq!(*prev, handle, "name '{name}' got two handles");
            }
        }
        assert_eq!(t.len(), 7 + 8);
        // Dense: every index below len is named, round-trips by name.
        for i in 0..t.len() {
            let name = t.name(by_name.values().find(|h| h.index() == i).copied().unwrap());
            assert!(name.is_some());
        }
    }

    #[test]
    fn retire_unbinds_name_but_keeps_the_handle() {
        let t = TenantInterner::new();
        let a = t.resolve("acme");
        assert_eq!(t.epoch(), 0);
        assert_eq!(t.retire("acme"), Some(a));
        assert_eq!(t.epoch(), 1);
        assert_eq!(t.retired_count(), 1);
        // The name no longer forward-resolves...
        assert_eq!(t.lookup("acme"), None);
        // ...but the retired handle still names itself.
        assert_eq!(&*t.name(a).unwrap(), "acme");
        // Retiring an unbound name is a no-op (no epoch bump).
        assert_eq!(t.retire("acme"), None);
        assert_eq!(t.retire("ghost"), None);
        assert_eq!(t.epoch(), 1);
        // Re-onboarding allocates a fresh handle; the old one is
        // never reissued.
        let a2 = t.resolve("acme");
        assert_ne!(a2, a);
        assert_eq!(a2.index(), 1);
        assert_eq!(t.len(), 2);
    }

    /// The satellite property: across arbitrary interleavings of
    /// onboarding and decommission, handles are never reused — every
    /// allocation is fresh, the allocation counter is dense, and the
    /// epoch counts exactly the successful retirements.
    #[test]
    fn prop_handles_are_never_reused_across_retirement_epochs() {
        prop::check(16, |g| {
            let shards = *g.pick(&[1usize, 2, 16]);
            let t = TenantInterner::with_shards(shards);
            let names: Vec<String> = (0..8).map(|i| format!("tenant-{i}")).collect();
            let mut ever_issued: Vec<TenantHandle> = Vec::new();
            let mut bound: HashMap<String, TenantHandle> = HashMap::new();
            let mut retires = 0u64;
            for _ in 0..g.usize(20..120) {
                let name = g.pick(&names).clone();
                if g.bool(0.35) {
                    let got = t.retire(&name);
                    let want = bound.remove(&name);
                    prop_assert!(got == want, "retire({name}): {got:?} vs {want:?}");
                    if want.is_some() {
                        retires += 1;
                    }
                } else {
                    let h = t.resolve(&name);
                    match bound.get(&name) {
                        Some(&prev) => prop_assert!(h == prev, "rebinding moved a live handle"),
                        None => {
                            prop_assert!(
                                !ever_issued.contains(&h),
                                "handle {h:?} was reused after retirement"
                            );
                            ever_issued.push(h);
                            bound.insert(name.clone(), h);
                        }
                    }
                }
            }
            // Dense: exactly len() handles issued, indices 0..len.
            prop_assert!(ever_issued.len() == t.len(), "allocation counter not dense");
            let mut idx: Vec<usize> = ever_issued.iter().map(|h| h.index()).collect();
            idx.sort_unstable();
            prop_assert!(idx == (0..t.len()).collect::<Vec<_>>(), "handle space has holes");
            prop_assert!(t.epoch() == retires, "epoch {} != retires {retires}", t.epoch());
            // Every handle ever issued still reverse-resolves.
            for h in &ever_issued {
                prop_assert!(t.name(*h).is_some(), "retired handle lost its name");
            }
            Ok(())
        });
    }

    /// Shard-count=1 equivalence: a single-shard interner (the old
    /// whole-map COW layout) and a multi-shard one expose identical
    /// observable behavior over the same operation sequence — only
    /// handle *numbering* may differ under concurrency, so the
    /// sequence here is deterministic and the binding surfaces must
    /// match exactly.
    #[test]
    fn prop_sharded_interner_is_oracle_exact_vs_single_shard() {
        prop::check(16, |g| {
            let a = TenantInterner::with_shards(1);
            let b = TenantInterner::with_shards(*g.pick(&[4usize, 16, 64]));
            let names: Vec<String> = (0..10).map(|i| format!("t{i}")).collect();
            for _ in 0..g.usize(20..150) {
                let name = g.pick(&names).clone();
                if g.bool(0.3) {
                    let (ra, rb) = (a.retire(&name), b.retire(&name));
                    prop_assert!(ra == rb, "retire({name}) diverged: {ra:?} vs {rb:?}");
                } else {
                    let (ha, hb) = (a.resolve(&name), b.resolve(&name));
                    prop_assert!(ha == hb, "resolve({name}) diverged: {ha:?} vs {hb:?}");
                }
                let probe = g.pick(&names);
                prop_assert!(a.lookup(probe) == b.lookup(probe), "lookup({probe}) diverged");
            }
            prop_assert!(a.len() == b.len(), "len diverged");
            prop_assert!(a.epoch() == b.epoch(), "epoch diverged");
            for i in 0..a.len() {
                let h = TenantHandle::from_index(i);
                prop_assert!(
                    a.name(h).as_deref() == b.name(h).as_deref(),
                    "name({i}) diverged"
                );
            }
            Ok(())
        });
    }
}
