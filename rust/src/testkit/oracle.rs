//! The sequential oracle engine: a deliberately naive, single-threaded
//! `Mutex`-and-`Vec` reference implementation of the MUSE engine
//! semantics, sharing **only the artifact and config types**
//! (`runtime::ModelPool`, `config::*`) with production. Everything the
//! production engine does with lock-free snapshots, sharded seqlock
//! rings, compiled pipelines and wait-free counters, the oracle does
//! with a handful of mutex-guarded maps and linear scans — slow,
//! obviously correct, and therefore usable as the ground truth the
//! real engine is diffed against (`testkit::harness`).
//!
//! # Equivalence contract
//!
//! The oracle's *arithmetic* mirrors the staged reference path
//! (`PipelineSpec::score_staged_one`'s operation order: per-expert
//! clamp → Eq. 3 rational map → clamp, then `num += c*w; den += w;
//! num/den`, then the Eq. 4 PWL lookup with precomputed segment
//! slopes) so that, against the row-independent `muse-sim-hlo`
//! interpreter, final scores agree **bitwise** with production — not
//! merely within a tolerance. The *structure* is naive on purpose: the
//! quantile lookup is a linear scan, the data lake is one
//! `Mutex<VecDeque>`, counters are a `Mutex<BTreeMap>`, and the
//! control plane mutates plain structs. Do not "optimise" this module;
//! its slowness is the point (see `benches/serving_bench.rs`,
//! "verification plane" section, for the measured gap).

use crate::config::{
    Condition, Intent, MuseConfig, PredictorConfig, RoutingConfig, ScoringRule, ShadowRule,
};
use crate::runtime::{ModelHandle, ModelPool};
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

/// Naive piecewise-linear quantile map: same validation and the same
/// arithmetic as `transforms::QuantileMap` (slopes precomputed as
/// `(refq[i+1]-refq[i]) / (src[i+1]-src[i])`, lookup evaluates
/// `refq[i] + (score - src[i]) * slopes[i]`), but the segment search
/// is a linear scan instead of a binary `partition_point`.
#[derive(Debug, Clone)]
pub struct OracleQuantile {
    src: Vec<f64>,
    refq: Vec<f64>,
    slopes: Vec<f64>,
}

impl OracleQuantile {
    pub fn new(src: Vec<f64>, refq: Vec<f64>) -> Result<OracleQuantile> {
        ensure!(src.len() == refq.len(), "quantile grids differ in length");
        ensure!(src.len() >= 2, "need at least 2 quantile points");
        ensure!(
            src.iter().all(|v| v.is_finite()) && refq.iter().all(|v| v.is_finite()),
            "quantiles must be finite"
        );
        for w in src.windows(2) {
            ensure!(w[1] > w[0], "source quantiles must be strictly increasing");
        }
        for w in refq.windows(2) {
            ensure!(w[1] >= w[0], "reference quantiles must be non-decreasing");
        }
        let slopes = src
            .windows(2)
            .zip(refq.windows(2))
            .map(|(s, r)| (r[1] - r[0]) / (s[1] - s[0]))
            .collect();
        Ok(OracleQuantile { src, refq, slopes })
    }

    /// Identity map on [0, 1], same knot arithmetic as
    /// `QuantileMap::identity`.
    pub fn identity(n_points: usize) -> Result<OracleQuantile> {
        let grid: Vec<f64> = (0..n_points)
            .map(|i| i as f64 / (n_points - 1) as f64)
            .collect();
        OracleQuantile::new(grid.clone(), grid)
    }

    pub fn source_quantiles(&self) -> &[f64] {
        &self.src
    }

    pub fn reference_quantiles(&self) -> &[f64] {
        &self.refq
    }

    /// Eq. 4 by linear scan. Bitwise-equal to `QuantileMap::apply` for
    /// every input: the segment index is the same (largest `i` with
    /// `src[i] <= score`) and the interpolation uses the identical
    /// operation sequence.
    pub fn apply(&self, score: f64) -> f64 {
        if score.is_nan() {
            return f64::NAN;
        }
        let n = self.src.len();
        if score <= self.src[0] {
            return self.refq[0];
        }
        if score >= self.src[n - 1] {
            return self.refq[n - 1];
        }
        let mut i = 0;
        while i + 1 < n && self.src[i + 1] <= score {
            i += 1;
        }
        self.refq[i] + (score - self.src[i]) * self.slopes[i]
    }
}

/// One recorded scoring event in the oracle lake.
#[derive(Debug, Clone, PartialEq)]
pub struct OracleRecord {
    pub tenant: String,
    pub predictor: String,
    pub score: f64,
    pub raw: f64,
    pub shadow: bool,
    pub seq: u64,
}

/// The oracle data lake: one mutex, one `VecDeque`, strict global FIFO
/// eviction at `cap`. (The production lake's per-stripe eviction
/// tracks this to within one stripe round; oracle-exactness traces
/// keep the cap above the event volume so the comparison is exact.)
pub struct OracleLake {
    cap: usize,
    inner: Mutex<OracleLakeInner>,
}

struct OracleLakeInner {
    records: VecDeque<OracleRecord>,
    next_seq: u64,
}

impl OracleLake {
    pub fn new(cap: usize) -> OracleLake {
        let cap = if cap == 0 {
            crate::datalake::DEFAULT_CAPACITY
        } else {
            cap
        };
        OracleLake {
            cap,
            inner: Mutex::new(OracleLakeInner {
                records: VecDeque::new(),
                next_seq: 0,
            }),
        }
    }

    pub fn append(&self, tenant: &str, predictor: &str, score: f64, raw: f64, shadow: bool) {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.records.push_back(OracleRecord {
            tenant: tenant.to_string(),
            predictor: predictor.to_string(),
            score,
            raw,
            shadow,
            seq,
        });
        while inner.records.len() > self.cap {
            inner.records.pop_front();
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All records for a pair in append order (live + shadow).
    pub fn records_for(&self, tenant: &str, predictor: &str) -> Vec<OracleRecord> {
        self.inner
            .lock()
            .unwrap()
            .records
            .iter()
            .filter(|r| r.tenant == tenant && r.predictor == predictor)
            .cloned()
            .collect()
    }

    pub fn raw_scores(&self, tenant: &str, predictor: &str) -> Vec<f64> {
        self.records_for(tenant, predictor)
            .into_iter()
            .map(|r| r.raw)
            .collect()
    }

    pub fn final_scores(&self, tenant: &str, predictor: &str) -> Vec<f64> {
        self.records_for(tenant, predictor)
            .into_iter()
            .map(|r| r.score)
            .collect()
    }

    pub fn count_for(&self, tenant: &str, predictor: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .records
            .iter()
            .filter(|r| r.tenant == tenant && r.predictor == predictor)
            .count()
    }

    /// Count per (tenant, predictor, shadow-flag) — the same shape
    /// `DataLake::counts` returns.
    pub fn counts(&self) -> BTreeMap<(String, String, bool), usize> {
        let mut out = BTreeMap::new();
        for r in self.inner.lock().unwrap().records.iter() {
            *out.entry((r.tenant.clone(), r.predictor.clone(), r.shadow))
                .or_insert(0) += 1;
        }
        out
    }
}

/// One deployed predictor in the oracle: the config, the acquired
/// container handles, per-expert betas (None = no `T^C`), and the
/// tenant quantile table as two plain maps.
struct OraclePredictor {
    config: PredictorConfig,
    handles: Vec<ModelHandle>,
    betas: Vec<Option<f64>>,
    default_q: Arc<OracleQuantile>,
    tenants: BTreeMap<String, Arc<OracleQuantile>>,
}

impl OraclePredictor {
    fn feature_dim(&self) -> usize {
        self.handles[0].feature_dim
    }

    fn quantile_for(&self, tenant: &str) -> &OracleQuantile {
        match self.tenants.get(tenant) {
            Some(q) => q,
            None => &self.default_q,
        }
    }

    /// Eq. 3 then A over one event's expert scores — the staged
    /// reference arithmetic, per event, no compilation.
    fn raw_score(&self, expert_scores: &[f32]) -> f64 {
        if self.handles.len() == 1 {
            // Identity aggregation (registry rule for single-model
            // predictors): the corrected score verbatim.
            let s = expert_scores[0] as f64;
            return match self.betas[0] {
                Some(b) => correct(b, s),
                None => s,
            };
        }
        let mut num = 0.0;
        let mut den = 0.0;
        for ((&s, beta), &w) in expert_scores
            .iter()
            .zip(&self.betas)
            .zip(&self.config.weights)
        {
            let c = match beta {
                Some(b) => correct(*b, s as f64),
                None => s as f64,
            };
            num += c * w;
            den += w;
        }
        num / den
    }
}

/// Eq. 3 with exactly `PosteriorCorrection::apply`'s operation order.
fn correct(beta: f64, score: f64) -> f64 {
    let s = score.clamp(0.0, 1.0);
    let denom = 1.0 - (1.0 - beta) * s;
    (beta * s / denom).clamp(0.0, 1.0)
}

/// The oracle's routing outcome (mirrors `coordinator::Resolution`).
#[derive(Debug, Clone, PartialEq)]
pub struct OracleResolution {
    pub live: String,
    pub shadows: Vec<String>,
}

/// The oracle's response to one scored event.
#[derive(Debug, Clone, PartialEq)]
pub struct OracleResponse {
    pub score: f64,
    pub raw: f64,
    pub predictor: String,
    pub shadow_count: usize,
}

/// One predictor's quantile-table state as the oracle models it
/// (sorted override names + the grids behind them).
pub struct OracleQuantileState {
    pub tenant_names: Vec<String>,
    pub default: Arc<OracleQuantile>,
    pub overrides: BTreeMap<String, Arc<OracleQuantile>>,
}

/// The sequential oracle engine. Every field sits behind a plain
/// mutex; every operation takes them in a fixed order (routing →
/// predictors → lake → counters) so the oracle itself can never
/// deadlock, and nothing here is clever.
pub struct OracleEngine {
    pool: Arc<ModelPool>,
    quantile_points: usize,
    /// `server.maxBatchEvents` — mirrored because the production
    /// engine enforces it as an admission check in `score_batch`.
    max_batch_events: usize,
    routing: Mutex<RoutingConfig>,
    predictors: Mutex<BTreeMap<String, OraclePredictor>>,
    pub lake: OracleLake,
    counters: Mutex<BTreeMap<String, u64>>,
    tenant_events: Mutex<BTreeMap<String, u64>>,
}

/// `FeatureStore::enrich` with an empty store (the harness never
/// seeds derived features or a fallback): payload first, zero-pad up
/// to the model dim, error only when the payload is *wider* than the
/// model expects.
fn enrich_like_empty_store(payload: &[f32], target_dim: usize) -> Result<Vec<f32>> {
    ensure!(
        payload.len() <= target_dim,
        "payload has {} features but model expects {target_dim}",
        payload.len()
    );
    let mut out = payload.to_vec();
    out.resize(target_dim, 0.0);
    Ok(out)
}

impl OracleEngine {
    /// Build from the same validated config the production engine was
    /// built from, against the oracle's **own** model pool (sharing
    /// only the artifact files, never runtime state).
    pub fn build(config: &MuseConfig, pool: Arc<ModelPool>) -> Result<OracleEngine> {
        config.validate()?;
        let quantile_points = pool.manifest().quantile_points;
        let oracle = OracleEngine {
            pool,
            quantile_points,
            max_batch_events: config.server.max_batch_events,
            routing: Mutex::new(config.routing.clone()),
            predictors: Mutex::new(BTreeMap::new()),
            lake: OracleLake::new(config.server.lake_max_records),
            counters: Mutex::new(BTreeMap::new()),
            tenant_events: Mutex::new(BTreeMap::new()),
        };
        for pc in &config.predictors {
            let initial = Arc::new(OracleQuantile::identity(quantile_points.max(2))?);
            oracle.deploy(pc, initial)?;
        }
        Ok(oracle)
    }

    fn bump(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn tenant_events(&self, tenant: &str) -> u64 {
        self.tenant_events.lock().unwrap().get(tenant).copied().unwrap_or(0)
    }

    /// The full per-tenant batch-event map (the harness compares it
    /// whole against `Engine::tenant_events`, in both directions — a
    /// key missing on either side is a divergence).
    pub fn tenant_events_snapshot(&self) -> BTreeMap<String, u64> {
        self.tenant_events.lock().unwrap().clone()
    }

    /// Sorted names of every deployed predictor.
    pub fn deployed(&self) -> Vec<String> {
        self.predictors.lock().unwrap().keys().cloned().collect()
    }

    /// One predictor's quantile-table state — compared against the
    /// production `QuantileTable` hooks.
    pub fn quantile_state(&self, predictor: &str) -> Option<OracleQuantileState> {
        let preds = self.predictors.lock().unwrap();
        let p = preds.get(predictor)?;
        Some(OracleQuantileState {
            tenant_names: p.tenants.keys().cloned().collect(),
            default: Arc::clone(&p.default_q),
            overrides: p.tenants.clone(),
        })
    }

    // ---------------------------------------------------------------
    // Routing (mirrors `Router::resolve_in`)
    // ---------------------------------------------------------------

    /// First-match live rule + deduped parallel shadow union, never
    /// shadowing onto the live target — `Router::resolve_in` verbatim,
    /// minus the `Arc<str>` sharing.
    pub fn resolve(&self, intent: &Intent) -> Result<OracleResolution> {
        let routing = self.routing.lock().unwrap();
        let mut live: Option<String> = None;
        for rule in &routing.scoring_rules {
            if rule.condition.matches(intent) {
                live = Some(rule.target_predictor.to_string());
                break;
            }
        }
        let Some(live) = live else {
            bail!("no scoring rule matches intent (tenant='{}')", intent.tenant);
        };
        let mut shadows: Vec<String> = Vec::new();
        for rule in &routing.shadow_rules {
            if rule.condition.matches(intent) {
                for t in &rule.target_predictors {
                    let t = t.to_string();
                    if t != live && !shadows.contains(&t) {
                        shadows.push(t);
                    }
                }
            }
        }
        Ok(OracleResolution { live, shadows })
    }

    // ---------------------------------------------------------------
    // Scoring (mirrors `Engine::score` / `Engine::score_batch`)
    // ---------------------------------------------------------------

    fn infer_one(&self, p: &OraclePredictor, features: &[f32]) -> Result<Vec<f32>> {
        let mut scores = Vec::with_capacity(p.handles.len());
        for h in &p.handles {
            let out = h.infer(features, 1)?;
            scores.push(out[0]);
        }
        Ok(scores)
    }

    /// Score one event end to end: route → infer → `T^C` → `A` →
    /// tenant `T^Q` → lake append → shadow mirrors — everything the
    /// production hot path does, executed sequentially under mutexes.
    pub fn score(&self, intent: &Intent, features: &[f32]) -> Result<OracleResponse> {
        let res = self.resolve(intent)?;
        let (score, raw) = {
            let preds = self.predictors.lock().unwrap();
            let p = preds
                .get(&res.live)
                .ok_or_else(|| anyhow!("routed to undeployed predictor '{}'", res.live))?;
            let enriched = enrich_like_empty_store(features, p.feature_dim())?;
            let expert_scores = self.infer_one(p, &enriched)?;
            let raw = p.raw_score(&expert_scores);
            (p.quantile_for(&intent.tenant).apply(raw), raw)
        };
        self.lake.append(&intent.tenant, &res.live, score, raw, false);
        // Shadow mirrors (production: async on the shadow pool; the
        // oracle mirrors inline — the harness drains the production
        // pool before diffing, so the end states agree). Inference
        // failures are swallowed exactly like production's
        // `if let Ok(..)` shadow task: no record, live response
        // unaffected.
        for shadow in &res.shadows {
            let preds = self.predictors.lock().unwrap();
            let Some(sp) = preds.get(shadow) else {
                drop(preds);
                self.bump("shadow_missing_predictor", 1);
                continue;
            };
            let Ok(enriched) = enrich_like_empty_store(features, sp.feature_dim()) else {
                drop(preds);
                self.bump("shadow_enrich_error", 1);
                continue;
            };
            let Ok(expert_scores) = self.infer_one(sp, &enriched) else {
                drop(preds);
                continue;
            };
            let sraw = sp.raw_score(&expert_scores);
            let sfinal = sp.quantile_for(&intent.tenant).apply(sraw);
            drop(preds);
            self.lake.append(&intent.tenant, shadow, sfinal, sraw, true);
            self.bump("testkit_shadow_mirrors_single", 1);
        }
        self.bump("requests_live", 1);
        Ok(OracleResponse {
            score,
            raw,
            predictor: res.live,
            shadow_count: res.shadows.len(),
        })
    }

    /// Score a batch with `Engine::score_batch`'s semantics: group by
    /// identical intent in first-appearance order, route once per
    /// group, commit lake records and per-tenant counters per group
    /// only after every group scored, responses in input order.
    pub fn score_batch(
        &self,
        reqs: &[(Intent, Vec<f32>)],
    ) -> Result<Vec<OracleResponse>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        ensure!(
            reqs.len() <= self.max_batch_events,
            "batch of {} events exceeds maxBatchEvents = {}",
            reqs.len(),
            self.max_batch_events
        );
        struct Group {
            first: usize,
            indices: Vec<usize>,
            resolution: OracleResolution,
        }
        let mut groups: Vec<Group> = Vec::new();
        for (i, (intent, _)) in reqs.iter().enumerate() {
            match groups.iter().position(|g| &reqs[g.first].0 == intent) {
                Some(gi) => groups[gi].indices.push(i),
                None => groups.push(Group {
                    first: i,
                    indices: vec![i],
                    resolution: self.resolve(intent)?,
                }),
            }
        }
        // Phase 1: score every group, no side effects.
        struct Scored {
            finals: Vec<f64>,
            raws: Vec<f64>,
        }
        let mut results: Vec<Scored> = Vec::with_capacity(groups.len());
        for g in &groups {
            let preds = self.predictors.lock().unwrap();
            let p = preds.get(&g.resolution.live).ok_or_else(|| {
                anyhow!("routed to undeployed predictor '{}'", g.resolution.live)
            })?;
            let tenant = &reqs[g.first].0.tenant;
            let mut finals = Vec::with_capacity(g.indices.len());
            let mut raws = Vec::with_capacity(g.indices.len());
            for &i in &g.indices {
                let enriched = enrich_like_empty_store(&reqs[i].1, p.feature_dim())?;
                let expert_scores = self.infer_one(p, &enriched)?;
                let raw = p.raw_score(&expert_scores);
                raws.push(raw);
                finals.push(p.quantile_for(tenant).apply(raw));
            }
            results.push(Scored { finals, raws });
        }
        // Phase 2: commit side effects, build responses in input order.
        let mut out: Vec<Option<OracleResponse>> = (0..reqs.len()).map(|_| None).collect();
        for (g, scored) in groups.iter().zip(&results) {
            let tenant = reqs[g.first].0.tenant.clone();
            for (slot, &i) in g.indices.iter().enumerate() {
                self.lake.append(
                    &tenant,
                    &g.resolution.live,
                    scored.finals[slot],
                    scored.raws[slot],
                    false,
                );
                out[i] = Some(OracleResponse {
                    score: scored.finals[slot],
                    raw: scored.raws[slot],
                    predictor: g.resolution.live.clone(),
                    shadow_count: g.resolution.shadows.len(),
                });
            }
            *self
                .tenant_events
                .lock()
                .unwrap()
                .entry(tenant.clone())
                .or_insert(0) += g.indices.len() as u64;
            // Batch shadow mirrors: whole sub-batch per shadow
            // target, skipped in full on dim mismatch (counted, like
            // production's re-enrich failure) or inference failure
            // (swallowed silently, like production's `.is_ok()` pool
            // task — never an error on the caller's path).
            for shadow in &g.resolution.shadows {
                let preds = self.predictors.lock().unwrap();
                let Some(sp) = preds.get(shadow) else {
                    drop(preds);
                    self.bump("shadow_missing_predictor", 1);
                    continue;
                };
                let mut mirrored: Vec<(f64, f64)> = Vec::with_capacity(g.indices.len());
                let mut dims_ok = true;
                let mut infer_ok = true;
                for &i in &g.indices {
                    let Ok(enriched) = enrich_like_empty_store(&reqs[i].1, sp.feature_dim())
                    else {
                        dims_ok = false;
                        break;
                    };
                    let Ok(expert_scores) = self.infer_one(sp, &enriched) else {
                        infer_ok = false;
                        break;
                    };
                    let sraw = sp.raw_score(&expert_scores);
                    mirrored.push((sp.quantile_for(&tenant).apply(sraw), sraw));
                }
                drop(preds);
                if !dims_ok {
                    self.bump("shadow_enrich_error", 1);
                    continue;
                }
                if !infer_ok {
                    continue;
                }
                for (sfinal, sraw) in mirrored {
                    self.lake.append(&tenant, shadow, sfinal, sraw, true);
                }
            }
        }
        self.bump("requests_batch", 1);
        self.bump("events_batch", reqs.len() as u64);
        Ok(out
            .into_iter()
            .map(|r| r.expect("every request belongs to exactly one group"))
            .collect())
    }

    // ---------------------------------------------------------------
    // Control plane (mirrors `ControlPlane` + `PredictorRegistry`)
    // ---------------------------------------------------------------

    /// Deploy with `PredictorRegistry::deploy`'s validation order:
    /// duplicate name, unknown model, beta range, aggregation-weight
    /// rules. Failed deploys release every acquired container.
    pub fn deploy(&self, cfg: &PredictorConfig, quantile: Arc<OracleQuantile>) -> Result<()> {
        let mut preds = self.predictors.lock().unwrap();
        if preds.contains_key(&cfg.name) {
            bail!("predictor '{}' is already deployed", cfg.name);
        }
        ensure!(!cfg.experts.is_empty(), "predictor '{}' needs >= 1 expert", cfg.name);
        let mut handles: Vec<ModelHandle> = Vec::with_capacity(cfg.experts.len());
        let mut betas: Vec<Option<f64>> = Vec::with_capacity(cfg.experts.len());
        let build = (|| -> Result<()> {
            for model in &cfg.experts {
                let handle = self.pool.acquire(model)?;
                let beta = handle.beta;
                // Acquired before validation, like the registry: the
                // failure path below releases every pushed handle.
                handles.push(handle);
                if cfg.posterior_correction {
                    ensure!(
                        beta > 0.0 && beta <= 1.0 && beta.is_finite(),
                        "undersampling ratio beta must be in (0, 1], got {beta}"
                    );
                    betas.push(Some(beta));
                } else {
                    betas.push(None);
                }
            }
            if cfg.experts.len() > 1 {
                // `Aggregation::weighted` validation.
                ensure!(!cfg.weights.is_empty(), "weights must be non-empty");
                ensure!(
                    cfg.weights.iter().all(|w| w.is_finite() && *w >= 0.0),
                    "weights must be finite and non-negative"
                );
                ensure!(
                    cfg.weights.iter().sum::<f64>() > 0.0,
                    "at least one weight must be positive"
                );
                ensure!(
                    cfg.weights.len() == cfg.experts.len(),
                    "aggregation arity mismatch"
                );
            }
            let dim = handles[0].feature_dim;
            ensure!(
                handles.iter().all(|h| h.feature_dim == dim),
                "experts disagree on feature_dim"
            );
            Ok(())
        })();
        if let Err(e) = build {
            for h in &handles {
                self.pool.release(&h.name);
            }
            return Err(e);
        }
        preds.insert(
            cfg.name.clone(),
            OraclePredictor {
                config: cfg.clone(),
                handles,
                betas,
                default_q: quantile,
                tenants: BTreeMap::new(),
            },
        );
        Ok(())
    }

    /// `ControlPlane::shadow_deploy`: deploy first (routing untouched
    /// on failure), then append the tenant's shadow rule.
    pub fn shadow_deploy(
        &self,
        cfg: &PredictorConfig,
        tenant: &str,
        quantile: Arc<OracleQuantile>,
    ) -> Result<()> {
        self.deploy(cfg, quantile)?;
        let mut routing = self.routing.lock().unwrap();
        routing.shadow_rules.push(ShadowRule {
            description: format!("shadow {} for {tenant}", cfg.name),
            condition: Condition {
                tenants: vec![tenant.to_string()],
                ..Condition::default()
            },
            target_predictors: vec![cfg.name.as_str().into()],
        });
        Ok(())
    }

    /// `ControlPlane::promote` verbatim, including the dedicated-rule
    /// insertion quirk and whole-rule shadow removal.
    pub fn promote(&self, tenant: &str, new_predictor: &str) -> Result<()> {
        ensure!(
            self.predictors.lock().unwrap().contains_key(new_predictor),
            "cannot promote undeployed predictor '{new_predictor}'"
        );
        let mut routing = self.routing.lock().unwrap();
        let intent = Intent {
            tenant: tenant.to_string(),
            ..Default::default()
        };
        let matched = routing
            .scoring_rules
            .iter()
            .position(|r| r.condition.matches(&intent));
        let Some(i) = matched else {
            bail!("no scoring rule matches tenant '{tenant}'");
        };
        if routing.scoring_rules[i].condition.tenants == vec![tenant.to_string()] {
            routing.scoring_rules[i].target_predictor = new_predictor.into();
        } else {
            routing.scoring_rules.insert(
                0,
                ScoringRule {
                    description: format!("promoted {new_predictor} for {tenant}"),
                    condition: Condition {
                        tenants: vec![tenant.to_string()],
                        ..Condition::default()
                    },
                    target_predictor: new_predictor.into(),
                },
            );
        }
        routing
            .shadow_rules
            .retain(|r| !r.target_predictors.iter().any(|t| &**t == new_predictor));
        Ok(())
    }

    /// `ControlPlane::decommission`: routing is stripped first (and
    /// stays stripped) even when the registry removal then errors.
    pub fn decommission(&self, predictor: &str) -> Result<()> {
        {
            let mut routing = self.routing.lock().unwrap();
            routing
                .scoring_rules
                .retain(|r| &*r.target_predictor != predictor);
            for rule in routing.shadow_rules.iter_mut() {
                rule.target_predictors.retain(|t| &**t != predictor);
            }
            routing.shadow_rules.retain(|r| !r.target_predictors.is_empty());
        }
        let removed = self.predictors.lock().unwrap().remove(predictor);
        let Some(p) = removed else {
            bail!("predictor '{predictor}' is not deployed");
        };
        for h in &p.handles {
            self.pool.release(&h.name);
        }
        Ok(())
    }

    /// `ControlPlane::install_custom_quantile` /
    /// `Predictor::install_tenant_quantile`.
    pub fn install_tenant_quantile(
        &self,
        predictor: &str,
        tenant: &str,
        map: Arc<OracleQuantile>,
    ) -> Result<()> {
        let mut preds = self.predictors.lock().unwrap();
        let p = preds
            .get_mut(predictor)
            .ok_or_else(|| anyhow!("unknown predictor '{predictor}'"))?;
        p.tenants.insert(tenant.to_string(), map);
        Ok(())
    }

    /// `Predictor::set_default_quantile` (tenant overrides carried
    /// along).
    pub fn set_default_quantile(&self, predictor: &str, map: Arc<OracleQuantile>) -> Result<()> {
        let mut preds = self.predictors.lock().unwrap();
        let p = preds
            .get_mut(predictor)
            .ok_or_else(|| anyhow!("unknown predictor '{predictor}'"))?;
        p.default_q = map;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::transforms::QuantileMap;
    use crate::util::prop;

    #[test]
    fn oracle_quantile_is_bitwise_equal_to_production_map() {
        prop::check(256, |g| {
            let n = g.usize(2..40);
            let src = g.monotone_grid(n, 0.0, 1.0);
            let refq = g.monotone_grid(n, 0.0, 1.0);
            let prod = QuantileMap::new(src.clone(), refq.clone()).unwrap();
            let oracle = OracleQuantile::new(src, refq).unwrap();
            for _ in 0..32 {
                let x = g.f64(-0.3..1.3);
                prop_assert!(
                    prod.apply(x).to_bits() == oracle.apply(x).to_bits(),
                    "maps diverge at {x}: prod {} vs oracle {}",
                    prod.apply(x),
                    oracle.apply(x)
                );
            }
            prop_assert!(prod.apply(f64::NAN).is_nan() && oracle.apply(f64::NAN).is_nan(), "NaN");
            Ok(())
        });
    }

    #[test]
    fn oracle_identity_matches_production_identity() {
        for n in [2usize, 3, 33, 129] {
            let prod = QuantileMap::identity(n).unwrap();
            let oracle = OracleQuantile::identity(n).unwrap();
            assert_eq!(prod.source_quantiles(), oracle.source_quantiles());
            assert_eq!(prod.reference_quantiles(), oracle.reference_quantiles());
        }
        assert!(OracleQuantile::identity(1).is_err());
    }

    #[test]
    fn oracle_lake_fifo_eviction_is_strict() {
        let lake = OracleLake::new(4);
        for i in 0..10 {
            lake.append("t", "p", i as f64, i as f64, false);
        }
        assert_eq!(lake.len(), 4);
        let raws = lake.raw_scores("t", "p");
        assert_eq!(raws, vec![6.0, 7.0, 8.0, 9.0]);
        assert_eq!(lake.count_for("t", "p"), 4);
    }

    #[test]
    fn oracle_correction_matches_posterior_correction() {
        use crate::transforms::PosteriorCorrection;
        prop::check(128, |g| {
            let beta = g.f64(0.001..1.0);
            let pc = PosteriorCorrection::new(beta).unwrap();
            let s = g.f64(-0.2..1.2);
            prop_assert!(
                pc.apply(s).to_bits() == correct(beta, s).to_bits(),
                "T^C diverges at {s} (beta {beta})"
            );
            Ok(())
        });
    }
}
