//! Seeded scenario generators for the model-based suite: tenant
//! topologies, intent/event streams (via the simulator's fraud/legit
//! workload mixtures), quantile-grid payloads, and control-plane
//! command interleavings (shadow-deploy / promote / decommission /
//! quantile-install storms).
//!
//! Everything is driven by `util::prop::Gen`, so the suites in
//! `tests/model_based.rs` inherit the prop framework's seed printing
//! and shrinking: a failing case panics with its seed, and
//! `prop::check_seeded(seed, 1, ...)` replays it exactly (recipe in
//! docs/TESTING.md).
//!
//! The generators maintain a lightweight routing mirror while emitting
//! commands so that storms stay *serving-valid* (no tenant is ever
//! left unroutable, live targets are never decommissioned) — with a
//! deliberate sprinkle of invalid commands (promote-to-ghost,
//! duplicate deploys) whose **error parity** the harness asserts
//! instead of their effects.

use crate::config::{
    Condition, Intent, LifecycleConfig, MuseConfig, PredictorConfig, QuantileMode, RoutingConfig,
    ScoringRule, ServerConfig, ShadowRule,
};
use crate::simulator::{TenantProfile, Workload, FEATURE_DIM};
use crate::util::prop::Gen;

/// The synthetic-fixture model roster (`runtime::simfix`).
pub const SIM_MODELS: [&str; 3] = ["s1", "s2", "s3"];

/// A generated serving topology: the boot config plus the tenant
/// universe the trace draws intents from.
#[derive(Debug, Clone)]
pub struct Topology {
    pub config: MuseConfig,
    pub tenants: Vec<String>,
}

/// One generated control-plane command. Quantile payloads carry
/// explicit grids (generated, not fitted) so the oracle and the engine
/// install byte-identical tables.
#[derive(Debug, Clone)]
pub enum Command {
    ShadowDeploy {
        cfg: PredictorConfig,
        tenant: String,
        src: Vec<f64>,
        refq: Vec<f64>,
    },
    Promote {
        tenant: String,
        predictor: String,
    },
    Decommission {
        predictor: String,
    },
    InstallTenantQuantile {
        predictor: String,
        tenant: String,
        src: Vec<f64>,
        refq: Vec<f64>,
    },
    SetDefaultQuantile {
        predictor: String,
        src: Vec<f64>,
        refq: Vec<f64>,
    },
}

/// One scoring call in a trace.
#[derive(Debug, Clone)]
pub enum Call {
    Single {
        intent: Intent,
        entity: String,
        features: Vec<f32>,
    },
    Batch(Vec<(Intent, String, Vec<f32>)>),
}

/// One phase: commands applied at the barrier, then events scored
/// (concurrently, for the swap-storm suite — commands never race
/// events, which is what makes the oracle's prediction exact).
#[derive(Debug, Clone)]
pub struct Phase {
    pub commands: Vec<Command>,
    pub calls: Vec<Call>,
}

/// A complete generated scenario.
#[derive(Debug, Clone)]
pub struct Trace {
    pub topology: Topology,
    pub phases: Vec<Phase>,
    /// Whether any (valid) decommission command is in the trace — the
    /// batcher-conservation check only holds without teardowns.
    pub has_decommission: bool,
}

fn intent_for(tenant: &str) -> Intent {
    Intent {
        tenant: tenant.to_string(),
        ..Intent::default()
    }
}

/// Random non-empty distinct expert subset of the sim roster.
fn expert_subset(g: &mut Gen) -> Vec<String> {
    let mut pool: Vec<&str> = SIM_MODELS.to_vec();
    g.rng().shuffle(&mut pool);
    let k = g.usize(1..(SIM_MODELS.len() + 1));
    pool[..k].iter().map(|m| m.to_string()).collect()
}

fn predictor_cfg(g: &mut Gen, name: &str) -> PredictorConfig {
    let experts = expert_subset(g);
    let weights: Vec<f64> = (0..experts.len()).map(|_| g.f64(0.1..2.0)).collect();
    PredictorConfig {
        name: name.to_string(),
        experts,
        weights,
        quantile_mode: QuantileMode::Identity,
        reference: "fraud-default".to_string(),
        posterior_correction: g.bool(0.5),
    }
}

fn grid_pair(g: &mut Gen) -> (Vec<f64>, Vec<f64>) {
    let n = g.usize(2..33);
    (g.monotone_grid(n, 0.0, 1.0), g.monotone_grid(n, 0.0, 1.0))
}

/// Generate a serving topology over the sim roster: 1-3 predictors,
/// 1-3 tenants each with a dedicated first-match rule, a catch-all,
/// and a sprinkle of shadow rules. Always passes `MuseConfig::validate`.
pub fn topology(g: &mut Gen) -> Topology {
    let n_preds = g.usize(1..4);
    let predictors: Vec<PredictorConfig> = (0..n_preds)
        .map(|i| predictor_cfg(g, &format!("p{i}")))
        .collect();
    let names: Vec<String> = predictors.iter().map(|p| p.name.clone()).collect();
    let n_tenants = g.usize(1..4);
    let tenants: Vec<String> = (0..n_tenants).map(|i| format!("t{i}")).collect();
    let mut scoring_rules: Vec<ScoringRule> = tenants
        .iter()
        .map(|t| ScoringRule {
            description: format!("dedicated {t}"),
            condition: Condition {
                tenants: vec![t.clone()],
                ..Condition::default()
            },
            target_predictor: g.pick(&names).as_str().into(),
        })
        .collect();
    scoring_rules.push(ScoringRule {
        description: "catch-all".to_string(),
        condition: Condition::default(),
        target_predictor: g.pick(&names).as_str().into(),
    });
    let mut shadow_rules: Vec<ShadowRule> = Vec::new();
    for t in &tenants {
        if g.bool(0.4) {
            let mut targets: Vec<std::sync::Arc<str>> =
                vec![g.pick(&names).as_str().into()];
            if n_preds > 1 && g.bool(0.4) {
                let extra = g.pick(&names).as_str();
                if !targets.iter().any(|x| &**x == extra) {
                    targets.push(extra.into());
                }
            }
            shadow_rules.push(ShadowRule {
                description: format!("shadow for {t}"),
                condition: Condition {
                    tenants: vec![t.clone()],
                    ..Condition::default()
                },
                target_predictors: targets,
            });
        }
    }
    let config = MuseConfig {
        routing: RoutingConfig {
            scoring_rules,
            shadow_rules,
        },
        predictors,
        server: ServerConfig {
            workers: 2,
            // Low enough that generated whole-batch calls (up to 24
            // events) sometimes trip the admission check — both sides
            // must reject those identically.
            max_batch_events: g.usize(16..33),
            ..ServerConfig::default()
        },
        lifecycle: LifecycleConfig::default(),
    };
    debug_assert!(config.validate().is_ok(), "generated config must validate");
    Topology { config, tenants }
}

/// Routing mirror used *during generation* to keep command storms
/// serving-valid. `None` tenant = the catch-all rule.
struct RoutingModel {
    rules: Vec<(Option<String>, String)>,
    deployed: Vec<String>,
}

impl RoutingModel {
    fn from_topology(t: &Topology) -> RoutingModel {
        RoutingModel {
            rules: t
                .config
                .routing
                .scoring_rules
                .iter()
                .map(|r| {
                    let tenant = r.condition.tenants.first().cloned();
                    (tenant, r.target_predictor.to_string())
                })
                .collect(),
            deployed: t.config.predictors.iter().map(|p| p.name.clone()).collect(),
        }
    }

    fn live_targets(&self) -> Vec<String> {
        self.rules.iter().map(|(_, p)| p.clone()).collect()
    }

    /// Mirror of `ControlPlane::promote`'s routing rewrite.
    fn promote(&mut self, tenant: &str, predictor: &str) {
        let matched = self.rules.iter().position(|(t, _)| match t {
            Some(t) => t == tenant,
            None => true, // catch-all matches everyone
        });
        if let Some(i) = matched {
            if self.rules[i].0.as_deref() == Some(tenant) {
                self.rules[i].1 = predictor.to_string();
            } else {
                self.rules
                    .insert(0, (Some(tenant.to_string()), predictor.to_string()));
            }
        }
    }

    fn decommission(&mut self, predictor: &str) {
        self.rules.retain(|(_, p)| p != predictor);
        self.deployed.retain(|p| p != predictor);
    }

    /// Deployed predictors not targeted by any scoring rule — safe to
    /// decommission without stranding a tenant.
    fn idle(&self) -> Vec<String> {
        let live = self.live_targets();
        self.deployed
            .iter()
            .filter(|p| !live.contains(p))
            .cloned()
            .collect()
    }
}

/// Generate the commands for one phase barrier, advancing the routing
/// mirror. Returns (commands, saw_valid_decommission).
fn phase_commands(
    g: &mut Gen,
    model: &mut RoutingModel,
    tenants: &[String],
    candidate_seq: &mut usize,
) -> (Vec<Command>, bool) {
    let mut commands = Vec::new();
    let mut decommissioned = false;
    let n = g.usize(0..4);
    for _ in 0..n {
        let roll = g.usize(0..10);
        match roll {
            // Shadow-deploy a fresh candidate for a random tenant.
            0..=3 => {
                let name = format!("cand{}", *candidate_seq);
                *candidate_seq += 1;
                let cfg = predictor_cfg(g, &name);
                let tenant = g.pick(tenants).clone();
                let (src, refq) = grid_pair(g);
                model.deployed.push(name);
                commands.push(Command::ShadowDeploy {
                    cfg,
                    tenant,
                    src,
                    refq,
                });
            }
            // Promote a deployed predictor for a random tenant.
            4..=5 => {
                let tenant = g.pick(tenants).clone();
                let predictor = g.pick(&model.deployed).clone();
                model.promote(&tenant, &predictor);
                commands.push(Command::Promote { tenant, predictor });
            }
            // Install a tenant override on a deployed predictor.
            6..=7 => {
                let predictor = g.pick(&model.deployed).clone();
                let tenant = g.pick(tenants).clone();
                let (src, refq) = grid_pair(g);
                commands.push(Command::InstallTenantQuantile {
                    predictor,
                    tenant,
                    src,
                    refq,
                });
            }
            // Swap a default map.
            8 => {
                let predictor = g.pick(&model.deployed).clone();
                let (src, refq) = grid_pair(g);
                commands.push(Command::SetDefaultQuantile {
                    predictor,
                    src,
                    refq,
                });
            }
            // Decommission an idle predictor, or emit a deliberately
            // invalid command for error-parity coverage
            // (promote-to-ghost, decommission-of-ghost, duplicate
            // deploy of an already-deployed name).
            _ => {
                let idle = model.idle();
                if !idle.is_empty() && g.bool(0.7) {
                    let predictor = g.pick(&idle).clone();
                    model.decommission(&predictor);
                    decommissioned = true;
                    commands.push(Command::Decommission { predictor });
                } else {
                    match g.usize(0..3) {
                        0 => commands.push(Command::Promote {
                            tenant: g.pick(tenants).clone(),
                            predictor: "ghost-undeployed".to_string(),
                        }),
                        1 => commands.push(Command::Decommission {
                            predictor: "ghost-undeployed".to_string(),
                        }),
                        _ => {
                            // Duplicate deploy: both sides must reject
                            // "already deployed" with routing untouched.
                            let name = g.pick(&model.deployed).clone();
                            let cfg = predictor_cfg(g, &name);
                            let tenant = g.pick(tenants).clone();
                            let (src, refq) = grid_pair(g);
                            commands.push(Command::ShadowDeploy {
                                cfg,
                                tenant,
                                src,
                                refq,
                            });
                        }
                    }
                }
            }
        }
    }
    (commands, decommissioned)
}

/// Generate a full trace: a topology plus 2-4 phases of command
/// barriers and event waves. `concurrent` traces emit only `Single`
/// calls (the swap-storm runner partitions them across threads);
/// sequential traces mix in whole-batch calls so `score_batch`'s
/// group-and-commit path is diffed too.
pub fn trace(g: &mut Gen, concurrent: bool) -> Trace {
    let topology = topology(g);
    let mut model = RoutingModel::from_topology(&topology);
    let mut candidate_seq = 0usize;
    let mut has_decommission = false;

    // Per-tenant workloads for realistic fraud/legit score mixtures
    // (plus a stranger tenant exercising the catch-all path).
    let mut tenant_names: Vec<String> = topology.tenants.clone();
    tenant_names.push("stranger".to_string());
    let mut workloads: Vec<(String, Workload)> = tenant_names
        .iter()
        .map(|t| {
            let profile = TenantProfile::new(t, g.u64(), g.f64(0.0..0.6), g.f64(0.0..0.4));
            (t.clone(), Workload::new(profile, g.u64()))
        })
        .collect();
    let mut next_event = |g: &mut Gen, entity_seq: &mut usize| {
        let wi = {
            // Mostly known tenants, occasionally the catch-all path.
            let n = workloads.len();
            if g.bool(0.12) {
                n - 1
            } else {
                g.usize(0..(n - 1).max(1))
            }
        };
        let (tenant, wl) = &mut workloads[wi];
        let e = wl.next_event();
        *entity_seq += 1;
        let mut features = e.features;
        // Occasional partial payloads: the engine's feature store is
        // empty in these traces, so enrichment zero-pads — the oracle
        // must model exactly that.
        if g.bool(0.08) {
            features.truncate(g.usize(1..FEATURE_DIM));
        }
        (intent_for(tenant), format!("e{entity_seq}"), features)
    };

    let mut entity_seq = 0usize;
    let n_phases = g.usize(2..5);
    let mut phases = Vec::with_capacity(n_phases);
    for pi in 0..n_phases {
        // Phase 0 starts from the boot config: events first, commands
        // only from the second phase on (so every trace exercises the
        // pristine world too).
        let (commands, decommissioned) = if pi == 0 {
            (Vec::new(), false)
        } else {
            phase_commands(g, &mut model, &topology.tenants, &mut candidate_seq)
        };
        has_decommission |= decommissioned;
        let mut calls = Vec::new();
        let n_singles = g.usize(24..72);
        for _ in 0..n_singles {
            let (intent, entity, features) = next_event(g, &mut entity_seq);
            calls.push(Call::Single {
                intent,
                entity,
                features,
            });
        }
        if !concurrent && g.bool(0.7) {
            let n_batch = g.usize(4..25);
            let batch: Vec<(Intent, String, Vec<f32>)> = (0..n_batch)
                .map(|_| next_event(g, &mut entity_seq))
                .collect();
            calls.push(Call::Batch(batch));
        }
        phases.push(Phase { commands, calls });
    }
    Trace {
        topology,
        phases,
        has_decommission,
    }
}

/// Parameters for one seamless-update storm (the metamorphic alert-
/// rate scenario; see `harness::run_update_storm`).
#[derive(Debug, Clone)]
pub struct UpdateStorm {
    /// The tenant's configured alert rate `a` (the decision-boundary
    /// contract under test).
    pub alert_rate: f64,
    pub experts: Vec<String>,
    pub weights: Vec<f64>,
    pub posterior_correction: bool,
    /// Calibration-period workload.
    pub calib: DriftSpec,
    /// Two successive drifts, each answered by a refit + promotion.
    pub drifts: Vec<DriftSpec>,
    /// Events used to fit each `T^Q` (also the mirror volume).
    pub n_fit: usize,
    /// Events per alert-rate evaluation window.
    pub n_eval: usize,
}

/// One workload regime for the storm.
#[derive(Debug, Clone)]
pub struct DriftSpec {
    pub profile_seed: u64,
    pub stream_seed: u64,
    pub shift_scale: f64,
    pub pattern1_frac: f64,
    pub fraud_rate: f64,
}

impl DriftSpec {
    pub fn workload(&self, tenant: &str) -> Workload {
        let profile = TenantProfile::new(
            tenant,
            self.profile_seed,
            self.shift_scale,
            self.pattern1_frac,
        )
        .with_fraud_rate(self.fraud_rate);
        Workload::new(profile, self.stream_seed)
    }
}

/// Generate one update storm: a calm calibration regime, then two
/// strong drifts (covariate shift + fraud-wave + attack-pattern flip)
/// that each force a refit and promotion.
pub fn update_storm(g: &mut Gen) -> UpdateStorm {
    let experts = expert_subset(g);
    let weights: Vec<f64> = (0..experts.len()).map(|_| g.f64(0.2..2.0)).collect();
    let calib = DriftSpec {
        profile_seed: g.u64(),
        stream_seed: g.u64(),
        shift_scale: g.f64(0.05..0.3),
        pattern1_frac: g.f64(0.02..0.15),
        fraud_rate: g.f64(0.015..0.03),
    };
    let drifts = (0..2)
        .map(|_| DriftSpec {
            profile_seed: g.u64(),
            stream_seed: g.u64(),
            shift_scale: g.f64(0.6..1.1),
            pattern1_frac: g.f64(0.6..0.9),
            fraud_rate: g.f64(0.08..0.15),
        })
        .collect();
    UpdateStorm {
        alert_rate: g.f64(0.08..0.16),
        experts,
        weights,
        posterior_correction: g.bool(0.5),
        calib,
        drifts,
        n_fit: 1400,
        n_eval: 1100,
    }
}
