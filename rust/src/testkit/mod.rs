//! The model-based verification plane.
//!
//! Four PRs of lock-free serving machinery implement one promise —
//! after a model swap, every tenant's decision boundary stays put —
//! and this module is how that promise gets *checked* instead of
//! reviewed. Three parts:
//!
//! * [`oracle`] — a deliberately naive, single-threaded,
//!   `Mutex`-and-`Vec` reference implementation of the engine
//!   semantics (route → `T^C` → `A` → `T^Q`, FIFO bounded lake,
//!   counters, the shadow→promote→decommission state machine) sharing
//!   only artifact/config types with production.
//! * [`gen`] — seeded generators for tenant topologies, event streams
//!   and control-plane command interleavings, built on
//!   `util::prop::Gen` so failures print replayable seeds.
//! * [`harness`] — the deterministic runner that replays one generated
//!   trace through both engines and diffs final scores bitwise
//!   (single-thread) or as multisets plus exact counts (concurrent
//!   swap storms), plus the seamless-update metamorphic check.
//!
//! Compiled only under `cfg(test)` or `--features testkit` (the self
//! dev-dependency in Cargo.toml turns the feature on for every dev
//! target). The driving suites live in `tests/model_based.rs`;
//! docs/TESTING.md documents the invariant catalog and the
//! failing-seed replay recipe.

pub mod gen;
pub mod harness;
pub mod oracle;

pub use gen::{Call, Command, DriftSpec, Phase, Topology, Trace, UpdateStorm};
pub use harness::{
    apply_command, base_seed, build_pair, check_batcher_conservation, check_logged,
    cluster_apply_command, diff_cluster_state, diff_state, run_cluster_trace,
    run_trace_concurrent, run_trace_single, run_update_storm, to_cluster_command,
    UpdateStormReport,
};
pub use oracle::{
    OracleEngine, OracleLake, OracleQuantile, OracleQuantileState, OracleRecord, OracleResponse,
};
