//! The deterministic oracle-diff harness: replays one generated trace
//! through the production [`Engine`] (real `SnapCell` snapshots,
//! sharded lake, dynamic batchers, shadow pool — optionally from N
//! concurrent client threads) **and** through the sequential
//! [`OracleEngine`], then diffs everything observable:
//!
//! * per-event responses — **bitwise** score equality (the sim-dialect
//!   interpreter is row-independent, so batching/coalescing cannot
//!   perturb a row; see docs/TESTING.md "Why bitwise is legal here"),
//! * the data lake — length, per-(tenant, predictor, shadow) counts,
//!   and per-pair score sequences (append-ordered single-threaded,
//!   multiset under concurrency),
//! * counters, per-tenant batch accounting, the deployed set, the
//!   published snapshot's entry set, and every predictor's quantile
//!   table (override key set + grids, via the `testkit` hooks),
//! * batcher event conservation (traces without teardowns).
//!
//! Control-plane commands are applied at **phase barriers** — never
//! racing events — which is exactly what makes the oracle's prediction
//! total even for concurrent swap storms: within a wave the routing
//! world is constant, and scores are interleaving-independent.
//!
//! The harness also owns the headline *seamless-update metamorphic
//! check* ([`run_update_storm`]): across generated drift + refit +
//! promotion storms, a tenant's alert rate at its configured threshold
//! must return to target after every promotion while the raw score
//! distribution demonstrably shifts — and must never be worse than the
//! counterfactual "keep the old transformation" world.

use crate::cluster::{
    ClusterCommand, ClusterOptions, FaultPoint, MuseCluster, NodeState, PoolFactory,
};
use crate::config::{Intent, MuseConfig, PredictorConfig, QuantileMode};
use crate::coordinator::{ControlPlane, Engine, ScoreRequest, ScoreResponse};
use crate::runtime::{Manifest, ModelPool, SimArtifacts};
use crate::testkit::gen::{Call, Command, Trace, UpdateStorm};
use crate::testkit::oracle::{OracleEngine, OracleQuantile, OracleResponse};
use crate::transforms::{quantile_fit, QuantileMap, ReferenceDistribution};
use crate::util::prop::PropResult;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Build the production engine and the sequential oracle from the same
/// config against the same artifact fixture — but **separate** model
/// pools, so the two sides share artifact bytes and config values and
/// nothing else.
pub fn build_pair(fix: &SimArtifacts, config: &MuseConfig) -> Result<(Engine, OracleEngine)> {
    let engine = Engine::build(config, Arc::new(ModelPool::new(fix.manifest()?)))?;
    let oracle = OracleEngine::build(config, Arc::new(ModelPool::new(fix.manifest()?)))?;
    Ok((engine, oracle))
}

fn bits_eq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits()
}

/// Apply one generated command to both sides and assert **outcome
/// parity** (Ok vs Err — messages may differ, effects are diffed
/// later).
pub fn apply_command(engine: &Engine, oracle: &OracleEngine, cmd: &Command) -> PropResult {
    let cp = ControlPlane::new(engine);
    let (e_ok, o_ok, label) = match cmd {
        Command::ShadowDeploy {
            cfg,
            tenant,
            src,
            refq,
        } => {
            let map = QuantileMap::new(src.clone(), refq.clone())
                .map_err(|e| format!("generated grid invalid: {e}"))?
                .shared();
            let omap = Arc::new(
                OracleQuantile::new(src.clone(), refq.clone())
                    .map_err(|e| format!("oracle grid invalid: {e}"))?,
            );
            (
                cp.shadow_deploy(cfg, tenant, map).is_ok(),
                oracle.shadow_deploy(cfg, tenant, omap).is_ok(),
                format!("shadow_deploy {} for {tenant}", cfg.name),
            )
        }
        Command::Promote { tenant, predictor } => (
            cp.promote(tenant, predictor).is_ok(),
            oracle.promote(tenant, predictor).is_ok(),
            format!("promote {predictor} for {tenant}"),
        ),
        Command::Decommission { predictor } => (
            cp.decommission(predictor).is_ok(),
            oracle.decommission(predictor).is_ok(),
            format!("decommission {predictor}"),
        ),
        Command::InstallTenantQuantile {
            predictor,
            tenant,
            src,
            refq,
        } => {
            let map = QuantileMap::new(src.clone(), refq.clone())
                .map_err(|e| format!("generated grid invalid: {e}"))?
                .shared();
            let omap = Arc::new(
                OracleQuantile::new(src.clone(), refq.clone())
                    .map_err(|e| format!("oracle grid invalid: {e}"))?,
            );
            (
                cp.install_custom_quantile(predictor, tenant, map).is_ok(),
                oracle.install_tenant_quantile(predictor, tenant, omap).is_ok(),
                format!("install_tenant_quantile {predictor}/{tenant}"),
            )
        }
        Command::SetDefaultQuantile {
            predictor,
            src,
            refq,
        } => {
            let e_ok = match engine.predictor(predictor) {
                Ok(p) => {
                    let map = QuantileMap::new(src.clone(), refq.clone())
                        .map_err(|e| format!("generated grid invalid: {e}"))?
                        .shared();
                    p.set_default_quantile(map);
                    engine.republish();
                    true
                }
                Err(_) => false,
            };
            let omap = Arc::new(
                OracleQuantile::new(src.clone(), refq.clone())
                    .map_err(|e| format!("oracle grid invalid: {e}"))?,
            );
            (
                e_ok,
                oracle.set_default_quantile(predictor, omap).is_ok(),
                format!("set_default_quantile {predictor}"),
            )
        }
    };
    if e_ok != o_ok {
        return Err(format!(
            "command outcome divergence on [{label}]: engine ok={e_ok}, oracle ok={o_ok}"
        ));
    }
    Ok(())
}

fn compare_responses(
    idx: usize,
    engine_resp: &std::result::Result<ScoreResponse, String>,
    oracle_resp: &std::result::Result<OracleResponse, String>,
) -> PropResult {
    match (engine_resp, oracle_resp) {
        (Ok(e), Ok(o)) => {
            if &*e.predictor != o.predictor {
                return Err(format!(
                    "event {idx}: routed to '{}' but oracle says '{}'",
                    e.predictor, o.predictor
                ));
            }
            if e.shadow_count != o.shadow_count {
                return Err(format!(
                    "event {idx}: shadow_count {} vs oracle {}",
                    e.shadow_count, o.shadow_count
                ));
            }
            if !bits_eq(e.score, o.score) {
                return Err(format!(
                    "event {idx}: score {:?} vs oracle {:?} (bitwise diff {:#x} vs {:#x}, predictor '{}')",
                    e.score,
                    o.score,
                    e.score.to_bits(),
                    o.score.to_bits(),
                    o.predictor
                ));
            }
            Ok(())
        }
        (Err(_), Err(_)) => Ok(()),
        (Ok(e), Err(oe)) => Err(format!(
            "event {idx}: engine scored {} but oracle errored: {oe}",
            e.score
        )),
        (Err(ee), Ok(o)) => Err(format!(
            "event {idx}: oracle scored {} but engine errored: {ee}",
            o.score
        )),
    }
}

fn to_request(intent: &Intent, entity: &str, features: &[f32]) -> ScoreRequest {
    ScoreRequest {
        intent: intent.clone(),
        entity: entity.to_string(),
        features: features.to_vec(),
    }
}

/// Replay a trace single-threaded: every event is scored on both sides
/// in lockstep with bitwise response comparison, then the final states
/// are diffed with append-order-exact lake sequences.
pub fn run_trace_single(fix: &SimArtifacts, trace: &Trace) -> PropResult {
    let (engine, oracle) =
        build_pair(fix, &trace.topology.config).map_err(|e| format!("build: {e:#}"))?;
    let mut event_idx = 0usize;
    for phase in &trace.phases {
        for cmd in &phase.commands {
            apply_command(&engine, &oracle, cmd)?;
        }
        for call in &phase.calls {
            match call {
                Call::Single {
                    intent,
                    entity,
                    features,
                } => {
                    let e = engine
                        .score(&to_request(intent, entity, features))
                        .map_err(|err| format!("{err:#}"));
                    let o = oracle
                        .score(intent, features)
                        .map_err(|err| format!("{err:#}"));
                    compare_responses(event_idx, &e, &o)?;
                    event_idx += 1;
                }
                Call::Batch(items) => {
                    let reqs: Vec<ScoreRequest> = items
                        .iter()
                        .map(|(i, en, f)| to_request(i, en, f))
                        .collect();
                    let oreqs: Vec<(Intent, Vec<f32>)> =
                        items.iter().map(|(i, _, f)| (i.clone(), f.clone())).collect();
                    let e = engine.score_batch(&reqs).map_err(|err| format!("{err:#}"));
                    let o = oracle.score_batch(&oreqs).map_err(|err| format!("{err:#}"));
                    match (&e, &o) {
                        (Ok(es), Ok(os)) => {
                            if es.len() != os.len() {
                                return Err(format!(
                                    "batch at event {event_idx}: {} vs oracle {}",
                                    es.len(),
                                    os.len()
                                ));
                            }
                            for (i, (er, or)) in es.iter().zip(os).enumerate() {
                                compare_responses(
                                    event_idx + i,
                                    &Ok(er.clone()),
                                    &Ok(or.clone()),
                                )?;
                            }
                        }
                        (Err(_), Err(_)) => {}
                        (a, b) => {
                            return Err(format!(
                                "batch outcome divergence at event {event_idx}: engine \
                                 ok={} oracle ok={}",
                                a.is_ok(),
                                b.is_ok()
                            ));
                        }
                    }
                    event_idx += items.len();
                }
            }
        }
        // Shadow mirrors must land before the next command barrier —
        // a decommission would otherwise race queued shadow work.
        engine.drain_shadows();
    }
    engine.drain_shadows();
    diff_state(&engine, &oracle, true)?;
    if !trace.has_decommission {
        check_batcher_conservation(&engine, &oracle)?;
    }
    Ok(())
}

/// Replay a trace with each phase's events scored from `threads`
/// concurrent client threads against the production engine (the swap
/// storm: promotions/deploys/teardowns land at the barriers between
/// waves). Per-event responses are still compared bitwise — scores are
/// interleaving-independent — and the final lake is compared as
/// multisets + exact counts.
pub fn run_trace_concurrent(fix: &SimArtifacts, trace: &Trace, threads: usize) -> PropResult {
    let (engine, oracle) =
        build_pair(fix, &trace.topology.config).map_err(|e| format!("build: {e:#}"))?;
    let mut event_base = 0usize;
    for phase in &trace.phases {
        for cmd in &phase.commands {
            apply_command(&engine, &oracle, cmd)?;
        }
        // Concurrent traces contain only Single calls (gen contract).
        let wave: Vec<(Intent, String, Vec<f32>)> = phase
            .calls
            .iter()
            .filter_map(|c| match c {
                Call::Single {
                    intent,
                    entity,
                    features,
                } => Some((intent.clone(), entity.clone(), features.clone())),
                Call::Batch(_) => None,
            })
            .collect();
        let mut engine_results: Vec<Option<std::result::Result<ScoreResponse, String>>> =
            (0..wave.len()).map(|_| None).collect();
        std::thread::scope(|s| {
            let engine = &engine;
            let wave = &wave;
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    s.spawn(move || {
                        let mut out: Vec<(usize, std::result::Result<ScoreResponse, String>)> =
                            Vec::new();
                        for (i, (intent, entity, features)) in wave.iter().enumerate() {
                            if i % threads != t {
                                continue;
                            }
                            let r = engine
                                .score(&to_request(intent, entity, features))
                                .map_err(|e| format!("{e:#}"));
                            out.push((i, r));
                        }
                        out
                    })
                })
                .collect();
            for h in handles {
                for (i, r) in h.join().expect("scoring thread panicked") {
                    engine_results[i] = Some(r);
                }
            }
        });
        engine.drain_shadows();
        for (i, (intent, _, features)) in wave.iter().enumerate() {
            let o = oracle.score(intent, features).map_err(|e| format!("{e:#}"));
            let e = engine_results[i]
                .take()
                .expect("every wave index was scored by exactly one thread");
            compare_responses(event_base + i, &e, &o)?;
        }
        event_base += wave.len();
    }
    engine.drain_shadows();
    diff_state(&engine, &oracle, false)
}

/// Diff everything observable between the production engine and the
/// oracle. `ordered` selects append-order-exact per-pair sequence
/// comparison (single-threaded replays) vs multiset comparison
/// (concurrent replays — interleaving decides lake order, scores
/// don't change).
pub fn diff_state(engine: &Engine, oracle: &OracleEngine, ordered: bool) -> PropResult {
    // Lake cardinality and per-(tenant, predictor, shadow) counts.
    let e_len = engine.lake.len();
    let o_len = oracle.lake.len();
    if e_len != o_len {
        return Err(format!("lake len {e_len} vs oracle {o_len}"));
    }
    let e_counts = engine.lake.counts();
    let o_counts = oracle.lake.counts();
    if e_counts != o_counts {
        return Err(format!(
            "lake counts diverge:\n  engine: {e_counts:?}\n  oracle: {o_counts:?}"
        ));
    }
    if engine.lake.forced_overwrites() != 0 || engine.lake.lost_appends() != 0 {
        return Err(format!(
            "lake degradation in a healthy run: forced={} lost={}",
            engine.lake.forced_overwrites(),
            engine.lake.lost_appends()
        ));
    }
    // Per-pair score sequences, and the O(1) count_for probe.
    let pairs: Vec<(String, String)> = {
        let mut v: Vec<(String, String)> = e_counts
            .keys()
            .map(|(t, p, _)| (t.clone(), p.clone()))
            .collect();
        v.sort();
        v.dedup();
        v
    };
    for (tenant, predictor) in &pairs {
        let e_cf = engine.lake.count_for(tenant, predictor);
        let o_cf = oracle.lake.count_for(tenant, predictor);
        if e_cf != o_cf {
            return Err(format!(
                "count_for({tenant},{predictor}) {e_cf} vs oracle {o_cf}"
            ));
        }
        let e_recs = engine.lake.records_for(tenant, predictor);
        let o_recs = oracle.lake.records_for(tenant, predictor);
        for shadow in [false, true] {
            let mut e_pairs: Vec<(u64, u64)> = e_recs
                .iter()
                .filter(|r| r.shadow == shadow)
                .map(|r| (r.score.to_bits(), r.raw_score.to_bits()))
                .collect();
            let mut o_pairs: Vec<(u64, u64)> = o_recs
                .iter()
                .filter(|r| r.shadow == shadow)
                .map(|r| (r.score.to_bits(), r.raw.to_bits()))
                .collect();
            // Shadow mirrors execute on a pool even single-threaded, so
            // their intra-pair order is scheduling; live order is exact
            // when the replay was sequential.
            if !ordered || shadow {
                e_pairs.sort_unstable();
                o_pairs.sort_unstable();
            }
            if e_pairs != o_pairs {
                return Err(format!(
                    "lake records diverge for ({tenant},{predictor},shadow={shadow}): \
                     {} vs oracle {} records (ordered={})",
                    e_pairs.len(),
                    o_pairs.len(),
                    ordered && !shadow
                ));
            }
        }
    }
    // Counters the data plane maintains.
    for name in [
        "requests_live",
        "requests_batch",
        "events_batch",
        "shadow_missing_predictor",
        "shadow_enrich_error",
    ] {
        let e = engine.counters.get(name);
        let o = oracle.counter(name);
        if e != o {
            return Err(format!("counter '{name}': engine {e} vs oracle {o}"));
        }
    }
    // Per-tenant batch accounting: full-map equality, so an engine
    // that silently stops accounting a tenant (missing key) diverges
    // just as loudly as a wrong count.
    let e_tenants: BTreeMap<String, u64> = engine.scored_events_snapshot();
    let o_tenants = oracle.tenant_events_snapshot();
    if e_tenants != o_tenants {
        return Err(format!(
            "tenant_events diverge:\n  engine: {e_tenants:?}\n  oracle: {o_tenants:?}"
        ));
    }
    // Deployment set: registry truth and the *published* snapshot.
    let e_deployed = engine.registry.names();
    let o_deployed = oracle.deployed();
    if e_deployed != o_deployed {
        return Err(format!(
            "deployed set diverges: engine {e_deployed:?} vs oracle {o_deployed:?}"
        ));
    }
    let snap_names = engine.snapshot_predictor_names();
    if snap_names != o_deployed {
        return Err(format!(
            "published snapshot {snap_names:?} lags oracle world {o_deployed:?}"
        ));
    }
    // Quantile tables: override key sets and exact grids.
    for name in &e_deployed {
        let p = engine
            .predictor(name)
            .map_err(|e| format!("predictor '{name}': {e:#}"))?;
        let table = p.quantile_table();
        let ostate = oracle
            .quantile_state(name)
            .ok_or_else(|| format!("oracle lost predictor '{name}'"))?;
        if table.tenant_names() != ostate.tenant_names {
            return Err(format!(
                "tenant-override set diverges for '{name}': {:?} vs oracle {:?}",
                table.tenant_names(),
                ostate.tenant_names
            ));
        }
        if table.default_map().source_quantiles() != ostate.default.source_quantiles()
            || table.default_map().reference_quantiles() != ostate.default.reference_quantiles()
        {
            return Err(format!("default T^Q grids diverge for '{name}'"));
        }
        for (tenant, omap) in &ostate.overrides {
            let emap = table.for_tenant(tenant);
            if emap.source_quantiles() != omap.source_quantiles()
                || emap.reference_quantiles() != omap.reference_quantiles()
            {
                return Err(format!("T^Q grids diverge for '{name}'/{tenant}"));
            }
        }
    }
    Ok(())
}

/// Event conservation: every single-path event (live request or shadow
/// mirror) passes through exactly one dynamic batcher, so the sum of
/// batcher event totals must equal the oracle's count of both. Only
/// valid for traces without decommissions (a teardown drops its
/// batcher's tally with it).
pub fn check_batcher_conservation(engine: &Engine, oracle: &OracleEngine) -> PropResult {
    let total: u64 = engine
        .batcher_event_totals()
        .iter()
        .map(|(_, s)| s.events)
        .sum();
    let expected =
        oracle.counter("requests_live") + oracle.counter("testkit_shadow_mirrors_single");
    if total != expected {
        return Err(format!(
            "batcher event conservation broken: batchers saw {total}, oracle counted {expected} \
             (live + single-path shadow mirrors)"
        ));
    }
    Ok(())
}

// -------------------------------------------------------------------
// The seamless-update metamorphic check
// -------------------------------------------------------------------

/// Outcome of one update storm (all rates are alert rates at the
/// tenant's threshold).
#[derive(Debug, Clone)]
pub struct UpdateStormReport {
    /// Alert rate after calibration, then after each promotion.
    pub rates: Vec<f64>,
    /// Counterfactual rate per drift: the *old* `T^Q` applied to the
    /// post-drift raw scores (what "swap nothing" would have served).
    pub counterfactual: Vec<f64>,
    /// Two-sample KS between calibration raws and each drift's raws
    /// (proof the input distribution actually moved).
    pub raw_ks: Vec<f64>,
    pub promotions: usize,
}

fn two_sample_ks(a: &[f64], b: &[f64]) -> f64 {
    let mut a = a.to_vec();
    let mut b = b.to_vec();
    a.sort_by(|x, y| x.partial_cmp(y).unwrap());
    b.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let (mut i, mut j) = (0usize, 0usize);
    let mut d = 0.0f64;
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            i += 1;
        } else {
            j += 1;
        }
        d = d.max((i as f64 / na - j as f64 / nb).abs());
    }
    d
}

fn drive_batches(
    engine: &Engine,
    wl: &mut crate::simulator::Workload,
    tenant: &str,
    n: usize,
    tag: &str,
) -> std::result::Result<Vec<f64>, String> {
    let mut finals = Vec::with_capacity(n);
    let mut done = 0usize;
    let mut chunk_id = 0usize;
    while done < n {
        let take = (n - done).min(200);
        let reqs: Vec<ScoreRequest> = (0..take)
            .map(|i| ScoreRequest {
                intent: Intent {
                    tenant: tenant.to_string(),
                    ..Intent::default()
                },
                entity: format!("{tag}-{chunk_id}-{i}"),
                features: wl.next_event().features,
            })
            .collect();
        let resps = engine
            .score_batch(&reqs)
            .map_err(|e| format!("score_batch ({tag}): {e:#}"))?;
        finals.extend(resps.iter().map(|r| r.score));
        done += take;
        chunk_id += 1;
    }
    Ok(finals)
}

/// Run one generated update storm end to end on the production engine:
/// calibrate a custom `T^Q` for the tenant, then for each generated
/// drift shadow-deploy a candidate, refit its `T^Q` from the mirrored
/// post-drift scores, promote it, and decommission the predecessor.
///
/// Asserts, per ISSUE acceptance: the tenant's alert rate at its
/// configured threshold stays within tolerance of the target across
/// ≥ 2 promotions, while the raw score distribution demonstrably
/// shifts — and each refit is never worse than the counterfactual
/// "keep the old transformation".
pub fn run_update_storm(
    fix: &SimArtifacts,
    storm: &UpdateStorm,
) -> std::result::Result<UpdateStormReport, String> {
    use crate::config::{Condition, RoutingConfig, ScoringRule, ServerConfig};
    let tenant = "acme";
    let live0 = PredictorConfig {
        name: "live0".to_string(),
        experts: storm.experts.clone(),
        weights: storm.weights.clone(),
        quantile_mode: QuantileMode::Custom,
        reference: "fraud-default".to_string(),
        posterior_correction: storm.posterior_correction,
    };
    let global = PredictorConfig {
        name: "global".to_string(),
        experts: vec!["s3".to_string()],
        weights: vec![1.0],
        quantile_mode: QuantileMode::Identity,
        reference: "fraud-default".to_string(),
        posterior_correction: false,
    };
    let config = MuseConfig {
        routing: RoutingConfig {
            scoring_rules: vec![
                ScoringRule {
                    description: "acme dedicated".to_string(),
                    condition: Condition {
                        tenants: vec![tenant.to_string()],
                        ..Condition::default()
                    },
                    target_predictor: "live0".into(),
                },
                ScoringRule {
                    description: "catch-all".to_string(),
                    condition: Condition::default(),
                    target_predictor: "global".into(),
                },
            ],
            shadow_rules: vec![],
        },
        predictors: vec![live0, global],
        server: ServerConfig {
            workers: 2,
            ..ServerConfig::default()
        },
        lifecycle: Default::default(),
    };
    let engine = Engine::build(&config, Arc::new(ModelPool::new(
        fix.manifest().map_err(|e| format!("manifest: {e:#}"))?,
    )))
    .map_err(|e| format!("build: {e:#}"))?;
    let cp = ControlPlane::new(&engine);
    let reference = ReferenceDistribution::fraud_default();
    let refq = reference.quantile_grid(engine.quantile_points);
    let a = storm.alert_rate;
    let threshold = reference.mixture.quantile(1.0 - a);
    let tol = (0.5 * a).max(0.035);
    let in_band = |rate: f64| (rate - a).abs() <= tol;
    let alert_rate = |finals: &[f64]| {
        finals.iter().filter(|&&s| s > threshold).count() as f64 / finals.len() as f64
    };

    // --- Calibration: fit the tenant's first custom T^Q -------------
    let mut calib_wl = storm.calib.workload(tenant);
    drive_batches(&engine, &mut calib_wl, tenant, storm.n_fit, "fit0")?;
    engine.drain_shadows();
    let calib_raws = engine.lake.raw_scores(tenant, "live0");
    let map0 = quantile_fit::fit_from_scores(&calib_raws, &refq)
        .map_err(|e| format!("calibration fit: {e:#}"))?
        .shared();
    cp.install_custom_quantile("live0", tenant, Arc::clone(&map0))
        .map_err(|e| format!("install map0: {e:#}"))?;
    let eval0 = drive_batches(&engine, &mut calib_wl, tenant, storm.n_eval, "eval0")?;
    let rate0 = alert_rate(&eval0);
    if !in_band(rate0) {
        return Err(format!(
            "calibrated alert rate {rate0:.4} misses target {a:.4} ± {tol:.4}"
        ));
    }

    let mut rates = vec![rate0];
    let mut counterfactual = Vec::new();
    let mut raw_ks = Vec::new();
    let mut prev_live = "live0".to_string();
    let mut prev_map: Arc<QuantileMap> = map0;
    let mut promotions = 0usize;

    for (k, drift) in storm.drifts.iter().enumerate() {
        let cand = format!("cand{}", k + 1);
        let cfg = PredictorConfig {
            name: cand.clone(),
            experts: storm.experts.clone(),
            weights: storm.weights.clone(),
            quantile_mode: QuantileMode::Custom,
            reference: "fraud-default".to_string(),
            posterior_correction: storm.posterior_correction,
        };
        let qp = engine.quantile_points.max(2);
        cp.shadow_deploy(
            &cfg,
            tenant,
            QuantileMap::identity(qp)
                .map_err(|e| format!("identity map: {e:#}"))?
                .shared(),
        )
        .map_err(|e| format!("shadow_deploy {cand}: {e:#}"))?;

        // Post-drift traffic: live on the incumbent (old T^Q),
        // mirrored in full to the candidate.
        let mut drift_wl = drift.workload(tenant);
        drive_batches(&engine, &mut drift_wl, tenant, storm.n_fit, &format!("drift{k}"))?;
        engine.drain_shadows();
        let drift_raws = engine.lake.raw_scores(tenant, &cand);
        if drift_raws.len() < refq.len() {
            return Err(format!(
                "candidate '{cand}' mirrored only {} samples (need {})",
                drift_raws.len(),
                refq.len()
            ));
        }
        let ks = two_sample_ks(&calib_raws, &drift_raws);
        raw_ks.push(ks);
        // Counterfactual: the predecessor's T^Q on post-drift raws.
        let cf = alert_rate(
            &drift_raws.iter().map(|&r| prev_map.apply(r)).collect::<Vec<f64>>(),
        );
        counterfactual.push(cf);

        // Refit from the mirrors, promote, tear the predecessor down.
        let mapk = quantile_fit::fit_from_scores(&drift_raws, &refq)
            .map_err(|e| format!("refit {cand}: {e:#}"))?
            .shared();
        cp.install_custom_quantile(&cand, tenant, Arc::clone(&mapk))
            .map_err(|e| format!("install {cand}: {e:#}"))?;
        cp.promote(tenant, &cand)
            .map_err(|e| format!("promote {cand}: {e:#}"))?;
        promotions += 1;
        cp.decommission(&prev_live)
            .map_err(|e| format!("decommission {prev_live}: {e:#}"))?;

        let evalk = drive_batches(&engine, &mut drift_wl, tenant, storm.n_eval, &format!("eval{k}"))?;
        let ratek = alert_rate(&evalk);
        if !in_band(ratek) {
            return Err(format!(
                "post-promotion #{} alert rate {ratek:.4} misses target {a:.4} ± {tol:.4} \
                 (counterfactual {cf:.4}, raw KS {ks:.3})",
                k + 1
            ));
        }
        if ks < 0.02 {
            return Err(format!(
                "drift #{} did not move the raw distribution (KS {ks:.4}) — the stability \
                 check would be vacuous",
                k + 1
            ));
        }
        // Metamorphic contrast: refitting must never serve a worse
        // alert rate than freezing the old transformation would have.
        if (ratek - a).abs() > (cf - a).abs() + 0.03 {
            return Err(format!(
                "refit #{} (rate {ratek:.4}) is worse than the counterfactual old-T^Q world \
                 ({cf:.4}) at target {a:.4}",
                k + 1
            ));
        }
        rates.push(ratek);
        prev_live = cand;
        prev_map = mapk;
    }

    // The final routing world: the last candidate serves the tenant,
    // predecessors are gone.
    let res = engine
        .router
        .resolve(&Intent {
            tenant: tenant.to_string(),
            ..Intent::default()
        })
        .map_err(|e| format!("final resolve: {e:#}"))?;
    if &*res.live != prev_live.as_str() {
        return Err(format!(
            "tenant is served by '{}' after the storm, expected '{prev_live}'",
            res.live
        ));
    }
    if engine.registry.get("live0").is_some() {
        return Err("decommissioned 'live0' still deployed".to_string());
    }
    Ok(UpdateStormReport {
        rates,
        counterfactual,
        raw_ks,
        promotions,
    })
}

// -------------------------------------------------------------------
// The cluster runner: N-node system vs the single oracle
// -------------------------------------------------------------------

/// Convert a generated command into its cluster twin, field for field,
/// so the replicated publish installs byte-identical state to what the
/// oracle applies.
pub fn to_cluster_command(cmd: &Command) -> ClusterCommand {
    match cmd {
        Command::ShadowDeploy {
            cfg,
            tenant,
            src,
            refq,
        } => ClusterCommand::ShadowDeploy {
            cfg: cfg.clone(),
            tenant: tenant.clone(),
            src: src.clone(),
            refq: refq.clone(),
        },
        Command::Promote { tenant, predictor } => ClusterCommand::Promote {
            tenant: tenant.clone(),
            predictor: predictor.clone(),
        },
        Command::Decommission { predictor } => ClusterCommand::Decommission {
            predictor: predictor.clone(),
        },
        Command::InstallTenantQuantile {
            predictor,
            tenant,
            src,
            refq,
        } => ClusterCommand::InstallTenantQuantile {
            predictor: predictor.clone(),
            tenant: tenant.clone(),
            src: src.clone(),
            refq: refq.clone(),
        },
        Command::SetDefaultQuantile {
            predictor,
            src,
            refq,
        } => ClusterCommand::SetDefaultQuantile {
            predictor: predictor.clone(),
            src: src.clone(),
            refq: refq.clone(),
        },
    }
}

/// Publish one generated command to the cluster and apply it to the
/// oracle, asserting **outcome parity**: a two-phase publish must
/// commit exactly when the sequential oracle accepts the command (a
/// validation nack on any replica aborts cluster-wide, which is only
/// correct because deterministic replicas nack in unison).
pub fn cluster_apply_command(
    cluster: &MuseCluster,
    oracle: &OracleEngine,
    cmd: &Command,
) -> PropResult {
    let c_ok = cluster.publish(to_cluster_command(cmd)).is_ok();
    let (o_ok, label) = match cmd {
        Command::ShadowDeploy {
            cfg, tenant, src, refq,
        } => {
            let omap = Arc::new(
                OracleQuantile::new(src.clone(), refq.clone())
                    .map_err(|e| format!("oracle grid invalid: {e}"))?,
            );
            (
                oracle.shadow_deploy(cfg, tenant, omap).is_ok(),
                format!("shadow_deploy {} for {tenant}", cfg.name),
            )
        }
        Command::Promote { tenant, predictor } => (
            oracle.promote(tenant, predictor).is_ok(),
            format!("promote {predictor} for {tenant}"),
        ),
        Command::Decommission { predictor } => (
            oracle.decommission(predictor).is_ok(),
            format!("decommission {predictor}"),
        ),
        Command::InstallTenantQuantile {
            predictor, tenant, src, refq,
        } => {
            let omap = Arc::new(
                OracleQuantile::new(src.clone(), refq.clone())
                    .map_err(|e| format!("oracle grid invalid: {e}"))?,
            );
            (
                oracle.install_tenant_quantile(predictor, tenant, omap).is_ok(),
                format!("install_tenant_quantile {predictor}/{tenant}"),
            )
        }
        Command::SetDefaultQuantile {
            predictor, src, refq,
        } => {
            let omap = Arc::new(
                OracleQuantile::new(src.clone(), refq.clone())
                    .map_err(|e| format!("oracle grid invalid: {e}"))?,
            );
            (
                oracle.set_default_quantile(predictor, omap).is_ok(),
                format!("set_default_quantile {predictor}"),
            )
        }
    };
    if c_ok != o_ok {
        return Err(format!(
            "publish outcome divergence on [{label}]: cluster ok={c_ok}, oracle ok={o_ok}"
        ));
    }
    Ok(())
}

/// One wave call's gateway outcome, recorded by the scoring threads
/// for the sequential oracle comparison afterwards.
enum WaveOut {
    Single(std::result::Result<crate::cluster::GatewayResponse, String>),
    Batch(std::result::Result<crate::cluster::GatewayBatch, String>),
}

/// Replay a trace against an N-node [`MuseCluster`] and the single
/// sequential [`OracleEngine`] — the cluster-wide seamlessness check.
///
/// Every phase's commands land as two-phase publishes at the barrier
/// (with outcome parity per [`cluster_apply_command`]); the phase's
/// events are then scored through the gateway from `threads` client
/// threads. Mid-storm the runner injects the failure schedule the
/// ISSUE demands: a crash armed to fire **mid-promotion**
/// (`CrashBeforeCommitApply` on the first publish flip after phase 0,
/// with a forced crash as fallback so every trace ends with a fenced
/// node), a `join` that must catch up by log replay before the last
/// phase, and a graceful `leave` right after it.
///
/// Checks, per event: bitwise score equality against the oracle and
/// an exact epoch attribution window (commands never race events, so
/// `epoch_lo == epoch_hi ==` the committed epoch read at the wave
/// barrier). At the end: cluster-aggregated conservation via
/// [`diff_cluster_state`].
pub fn run_cluster_trace(
    fix: &SimArtifacts,
    trace: &Trace,
    nodes: usize,
    threads: usize,
) -> PropResult {
    let root = fix.root().clone();
    let factory: PoolFactory =
        Box::new(move || Ok(Arc::new(ModelPool::new(Manifest::load(&root)?))));
    let cluster = MuseCluster::build(
        &trace.topology.config,
        ClusterOptions {
            nodes,
            ack_timeout: std::time::Duration::from_secs(2),
        },
        factory,
    )
    .map_err(|e| format!("cluster build: {e:#}"))?;
    let oracle = OracleEngine::build(
        &trace.topology.config,
        Arc::new(ModelPool::new(
            fix.manifest().map_err(|e| format!("manifest: {e:#}"))?,
        )),
    )
    .map_err(|e| format!("oracle build: {e:#}"))?;

    let n_phases = trace.phases.len();
    let mut victim: Option<crate::cluster::NodeId> = None;
    let mut joined: Option<crate::cluster::NodeId> = None;
    let mut event_idx = 0usize;

    for (pi, phase) in trace.phases.iter().enumerate() {
        if pi == 1 {
            // Arm the mid-promotion crash: the first committed publish
            // from here on kills this node between stage-ack and
            // commit-apply, so it is fenced at the *old* epoch.
            let v = cluster.serving_nodes()[0].id;
            cluster
                .arm_fault(v, FaultPoint::CrashBeforeCommitApply)
                .map_err(|e| format!("arm_fault: {e:#}"))?;
            victim = Some(v);
        }
        if pi + 1 == n_phases && n_phases > 1 {
            // Join mid-storm: the newcomer replays the committed log
            // (outside the membership) and then takes traffic...
            let id = cluster.join().map_err(|e| format!("join: {e:#}"))?;
            joined = Some(id);
            // ...while another node leaves gracefully.
            let leaver = cluster
                .serving_nodes()
                .iter()
                .map(|n| n.id)
                .find(|&id2| id2 != id && Some(id2) != victim);
            if let Some(leaver) = leaver {
                cluster.leave(leaver).map_err(|e| format!("leave: {e:#}"))?;
            }
        }
        for cmd in &phase.commands {
            cluster_apply_command(&cluster, &oracle, cmd)?;
        }

        // The wave: whole calls partitioned across client threads —
        // a batch is one request and lands wholly on one node.
        let epoch = cluster.committed_epoch();
        let gw = cluster.gateway();
        let mut results: Vec<Option<WaveOut>> = (0..phase.calls.len()).map(|_| None).collect();
        std::thread::scope(|s| {
            let gw = &gw;
            let calls = &phase.calls;
            let handles: Vec<_> = (0..threads.max(1))
                .map(|t| {
                    s.spawn(move || {
                        let mut out: Vec<(usize, WaveOut)> = Vec::new();
                        for (i, call) in calls.iter().enumerate() {
                            if i % threads.max(1) != t {
                                continue;
                            }
                            let r = match call {
                                Call::Single {
                                    intent,
                                    entity,
                                    features,
                                } => WaveOut::Single(
                                    gw.score(&to_request(intent, entity, features))
                                        .map_err(|e| format!("{e:#}")),
                                ),
                                Call::Batch(items) => {
                                    let reqs: Vec<ScoreRequest> = items
                                        .iter()
                                        .map(|(i2, en, f)| to_request(i2, en, f))
                                        .collect();
                                    WaveOut::Batch(
                                        gw.score_batch(&reqs).map_err(|e| format!("{e:#}")),
                                    )
                                }
                            };
                            out.push((i, r));
                        }
                        out
                    })
                })
                .collect();
            for h in handles {
                for (i, r) in h.join().expect("cluster scoring thread panicked") {
                    results[i] = Some(r);
                }
            }
        });

        // Sequential oracle pass + bitwise/epoch comparison in order.
        for (call, out) in phase.calls.iter().zip(results.iter_mut()) {
            let out = out.take().expect("every call scored by exactly one thread");
            match (call, out) {
                (
                    Call::Single {
                        intent, features, ..
                    },
                    WaveOut::Single(e),
                ) => {
                    let o = oracle.score(intent, features).map_err(|err| format!("{err:#}"));
                    if let Ok(g) = &e {
                        if g.epoch_lo != epoch || g.epoch_hi != epoch {
                            return Err(format!(
                                "event {event_idx}: epoch window [{}, {}] off the barrier \
                                 epoch {epoch} (node {})",
                                g.epoch_lo, g.epoch_hi, g.node
                            ));
                        }
                    }
                    compare_responses(event_idx, &e.map(|g| g.resp), &o)?;
                    event_idx += 1;
                }
                (Call::Batch(items), WaveOut::Batch(e)) => {
                    let oreqs: Vec<(Intent, Vec<f32>)> = items
                        .iter()
                        .map(|(i2, _, f)| (i2.clone(), f.clone()))
                        .collect();
                    let o = oracle.score_batch(&oreqs).map_err(|err| format!("{err:#}"));
                    match (&e, &o) {
                        (Ok(gb), Ok(os)) => {
                            if gb.epoch_lo != epoch || gb.epoch_hi != epoch {
                                return Err(format!(
                                    "batch at event {event_idx}: epoch window [{}, {}] off \
                                     the barrier epoch {epoch} (node {})",
                                    gb.epoch_lo, gb.epoch_hi, gb.node
                                ));
                            }
                            if gb.resps.len() != os.len() {
                                return Err(format!(
                                    "batch at event {event_idx}: {} vs oracle {}",
                                    gb.resps.len(),
                                    os.len()
                                ));
                            }
                            for (i, (er, or)) in gb.resps.iter().zip(os).enumerate() {
                                compare_responses(
                                    event_idx + i,
                                    &Ok(er.clone()),
                                    &Ok(or.clone()),
                                )?;
                            }
                        }
                        (Err(_), Err(_)) => {}
                        (a, b) => {
                            return Err(format!(
                                "batch outcome divergence at event {event_idx}: cluster \
                                 ok={} oracle ok={}",
                                a.is_ok(),
                                b.is_ok()
                            ));
                        }
                    }
                    event_idx += items.len();
                }
                _ => return Err("wave result shape mismatch".to_string()),
            }
        }
        // Shadow mirrors settle before the next command barrier, on
        // every engine that may have scored (including fenced ones).
        for node in cluster.nodes() {
            node.engine.drain_shadows();
        }
    }

    // The armed crash only fires on a committed flip; if the storm
    // never published a valid command after arming, force the death so
    // every trace still ends with a fenced node in the accounting.
    if let Some(v) = victim {
        let node = cluster
            .nodes()
            .into_iter()
            .find(|n| n.id == v)
            .ok_or_else(|| "victim vanished from the node ledger".to_string())?;
        if node.state() == NodeState::Serving && cluster.serving_nodes().len() > 1 {
            cluster.crash(v).map_err(|e| format!("forced crash: {e:#}"))?;
        }
    }
    let _ = joined; // the join is asserted through diff_cluster_state
    for node in cluster.nodes() {
        node.engine.drain_shadows();
    }
    diff_cluster_state(&cluster, &oracle, !trace.has_decommission)
}

/// Diff the cluster against the oracle:
///
/// * **aggregates over every node ever created** (serving, left,
///   crashed — fenced engines keep their scored history): lake
///   length, per-(tenant, predictor, shadow) record multisets,
///   `count_for`, data-plane counters, per-tenant batch accounting —
///   each event was scored on exactly one node, so the cluster-wide
///   sums must equal the single oracle **exactly**;
/// * **per serving node**: the replicated control-plane state — the
///   deployed set, the published snapshot's entry set and every
///   quantile table must equal the oracle's world on *each* replica
///   (left/crashed nodes are excluded: they are fenced at an older
///   epoch by design);
/// * optionally (traces without teardowns), cluster-wide batcher
///   event conservation.
pub fn diff_cluster_state(
    cluster: &MuseCluster,
    oracle: &OracleEngine,
    check_conservation: bool,
) -> PropResult {
    let all = cluster.nodes();
    // Lake cardinality and per-(tenant, predictor, shadow) counts.
    let c_len: usize = all.iter().map(|n| n.engine.lake.len()).sum();
    let o_len = oracle.lake.len();
    if c_len != o_len {
        return Err(format!(
            "cluster lake len {c_len} (over {} nodes) vs oracle {o_len}",
            all.len()
        ));
    }
    let mut c_counts: BTreeMap<(String, String, bool), usize> = BTreeMap::new();
    for n in &all {
        for (k, v) in n.engine.lake.counts() {
            *c_counts.entry(k).or_insert(0) += v;
        }
    }
    let o_counts = oracle.lake.counts();
    if c_counts != o_counts {
        return Err(format!(
            "cluster lake counts diverge:\n  cluster: {c_counts:?}\n  oracle: {o_counts:?}"
        ));
    }
    for n in &all {
        if n.engine.lake.forced_overwrites() != 0 || n.engine.lake.lost_appends() != 0 {
            return Err(format!(
                "lake degradation on node {}: forced={} lost={}",
                n.id,
                n.engine.lake.forced_overwrites(),
                n.engine.lake.lost_appends()
            ));
        }
    }
    // Per-pair record multisets, merged across nodes.
    let pairs: Vec<(String, String)> = {
        let mut v: Vec<(String, String)> = c_counts
            .keys()
            .map(|(t, p, _)| (t.clone(), p.clone()))
            .collect();
        v.sort();
        v.dedup();
        v
    };
    for (tenant, predictor) in &pairs {
        let c_cf: usize = all
            .iter()
            .map(|n| n.engine.lake.count_for(tenant, predictor))
            .sum();
        let o_cf = oracle.lake.count_for(tenant, predictor);
        if c_cf != o_cf {
            return Err(format!(
                "cluster count_for({tenant},{predictor}) {c_cf} vs oracle {o_cf}"
            ));
        }
        for shadow in [false, true] {
            let mut c_pairs: Vec<(u64, u64)> = all
                .iter()
                .flat_map(|n| n.engine.lake.records_for(tenant, predictor))
                .filter(|r| r.shadow == shadow)
                .map(|r| (r.score.to_bits(), r.raw_score.to_bits()))
                .collect();
            let mut o_pairs: Vec<(u64, u64)> = oracle
                .lake
                .records_for(tenant, predictor)
                .iter()
                .filter(|r| r.shadow == shadow)
                .map(|r| (r.score.to_bits(), r.raw.to_bits()))
                .collect();
            c_pairs.sort_unstable();
            o_pairs.sort_unstable();
            if c_pairs != o_pairs {
                return Err(format!(
                    "cluster lake records diverge for ({tenant},{predictor},shadow={shadow}): \
                     {} vs oracle {} records",
                    c_pairs.len(),
                    o_pairs.len()
                ));
            }
        }
    }
    // Data-plane counters, summed cluster-wide.
    for name in [
        "requests_live",
        "requests_batch",
        "events_batch",
        "shadow_missing_predictor",
        "shadow_enrich_error",
    ] {
        let c: u64 = all.iter().map(|n| n.engine.counters.get(name)).sum();
        let o = oracle.counter(name);
        if c != o {
            return Err(format!("cluster counter '{name}': {c} vs oracle {o}"));
        }
    }
    // Per-tenant batch accounting, merged cluster-wide.
    let mut c_tenants: BTreeMap<String, u64> = BTreeMap::new();
    for n in &all {
        for (k, v) in n.engine.scored_events_snapshot() {
            *c_tenants.entry(k).or_insert(0) += v;
        }
    }
    let o_tenants = oracle.tenant_events_snapshot();
    if c_tenants != o_tenants {
        return Err(format!(
            "cluster tenant_events diverge:\n  cluster: {c_tenants:?}\n  oracle: {o_tenants:?}"
        ));
    }
    // The replicated control-plane state, on every *serving* replica.
    let serving = cluster.serving_nodes();
    if serving.is_empty() {
        return Err("no serving nodes left at the end of the trace".to_string());
    }
    for n in &serving {
        diff_node_control_state(n.id, &n.engine, oracle)?;
    }
    // Batcher event conservation, cluster-wide.
    if check_conservation {
        let total: u64 = all
            .iter()
            .flat_map(|n| n.engine.batcher_event_totals())
            .map(|(_, s)| s.events)
            .sum();
        let expected =
            oracle.counter("requests_live") + oracle.counter("testkit_shadow_mirrors_single");
        if total != expected {
            return Err(format!(
                "cluster batcher conservation broken: batchers saw {total}, oracle counted \
                 {expected} (live + single-path shadow mirrors)"
            ));
        }
    }
    Ok(())
}

/// One serving node's control-plane state vs the oracle's world: the
/// deployed set, the published snapshot's entry set and every
/// predictor's quantile table (override key sets + exact grids).
fn diff_node_control_state(
    id: crate::cluster::NodeId,
    engine: &Engine,
    oracle: &OracleEngine,
) -> PropResult {
    let e_deployed = engine.registry.names();
    let o_deployed = oracle.deployed();
    if e_deployed != o_deployed {
        return Err(format!(
            "node {id}: deployed set diverges: {e_deployed:?} vs oracle {o_deployed:?}"
        ));
    }
    let snap_names = engine.snapshot_predictor_names();
    if snap_names != o_deployed {
        return Err(format!(
            "node {id}: published snapshot {snap_names:?} lags oracle world {o_deployed:?}"
        ));
    }
    for name in &e_deployed {
        let p = engine
            .predictor(name)
            .map_err(|e| format!("node {id}: predictor '{name}': {e:#}"))?;
        let table = p.quantile_table();
        let ostate = oracle
            .quantile_state(name)
            .ok_or_else(|| format!("oracle lost predictor '{name}'"))?;
        if table.tenant_names() != ostate.tenant_names {
            return Err(format!(
                "node {id}: tenant-override set diverges for '{name}': {:?} vs oracle {:?}",
                table.tenant_names(),
                ostate.tenant_names
            ));
        }
        if table.default_map().source_quantiles() != ostate.default.source_quantiles()
            || table.default_map().reference_quantiles() != ostate.default.reference_quantiles()
        {
            return Err(format!("node {id}: default T^Q grids diverge for '{name}'"));
        }
        for (tenant, omap) in &ostate.overrides {
            let emap = table.for_tenant(tenant);
            if emap.source_quantiles() != omap.source_quantiles()
                || emap.reference_quantiles() != omap.reference_quantiles()
            {
                return Err(format!("node {id}: T^Q grids diverge for '{name}'/{tenant}"));
            }
        }
    }
    Ok(())
}

// -------------------------------------------------------------------
// CI replay plumbing
// -------------------------------------------------------------------

/// Base seed for a suite: `MUSE_MB_SEED` (decimal or 0x-hex) when set
/// — the CI seed matrix — else the fixed default. A malformed value
/// **panics** instead of silently falling back: this env var is the
/// replay mechanism, and replaying the wrong seeds while reporting
/// green would be worse than no replay at all.
pub fn base_seed(default: u64) -> u64 {
    match std::env::var("MUSE_MB_SEED") {
        Ok(v) => {
            let v = v.trim();
            let parsed = match v.strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => v.parse::<u64>(),
            };
            parsed.unwrap_or_else(|e| {
                panic!("MUSE_MB_SEED '{v}' is not a u64 (decimal or 0x-hex): {e}")
            })
        }
        Err(_) => default,
    }
}

/// Run a seeded property and, on failure, persist the panic message
/// (which carries the failing seed) to
/// `target/model-based-seeds/<name>.txt` before re-panicking — CI
/// uploads that directory as the failing-seed artifact.
pub fn check_logged<F>(name: &str, base: u64, cases: u64, prop: F)
where
    F: Fn(&mut crate::util::prop::Gen) -> PropResult,
{
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        crate::util::prop::check_seeded(base, cases, &prop);
    }));
    if let Err(payload) = outcome {
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "non-string panic payload".to_string());
        let dir = std::path::Path::new("target").join("model-based-seeds");
        let _ = std::fs::create_dir_all(&dir);
        let _ = std::fs::write(
            dir.join(format!("{name}.txt")),
            format!(
                "suite: {name}\nbase_seed: {base:#x}\nreplay: MUSE_MB_SEED={base:#x} cargo test \
                 --test model_based {name}\n\n{msg}\n"
            ),
        );
        std::panic::resume_unwind(payload);
    }
}
