//! Threshold metrics: Recall@FPR (the paper's Section 3.2 headline:
//! "+1.1 pp Recall at 1% FPR"), alert rates, and AUC.

/// Recall at a fixed false-positive rate: choose the score threshold
/// whose FPR is closest to (but not above) `target_fpr`, then report
/// the recall (TPR) at that threshold. Ties in score are handled by
/// treating equal scores atomically.
pub fn recall_at_fpr(scores: &[f64], labels: &[f64], target_fpr: f64) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos: f64 = labels.iter().sum();
    let n_neg = labels.len() as f64 - n_pos;
    if n_pos == 0.0 || n_neg == 0.0 {
        return 0.0;
    }
    // Sort descending by score; sweep thresholds.
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).expect("NaN score"));

    let mut tp = 0.0;
    let mut fp = 0.0;
    let mut best_recall = 0.0;
    let mut i = 0;
    while i < idx.len() {
        // Consume the whole tie-group atomically.
        let s = scores[idx[i]];
        while i < idx.len() && scores[idx[i]] == s {
            if labels[idx[i]] > 0.5 {
                tp += 1.0;
            } else {
                fp += 1.0;
            }
            i += 1;
        }
        let fpr = fp / n_neg;
        if fpr <= target_fpr {
            best_recall = tp / n_pos;
        } else {
            break;
        }
    }
    best_recall
}

/// Alert rate at a fixed score threshold: share of events with
/// score >= threshold (what client-side decision rules compute).
pub fn alert_rate(scores: &[f64], threshold: f64) -> f64 {
    if scores.is_empty() {
        return 0.0;
    }
    scores.iter().filter(|&&s| s >= threshold).count() as f64 / scores.len() as f64
}

/// Rank-based AUC (Mann-Whitney), tie-aware via average ranks.
pub fn auc(scores: &[f64], labels: &[f64]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    let n_pos: f64 = labels.iter().sum();
    let n_neg = n as f64 - n_pos;
    if n_pos == 0.0 || n_neg == 0.0 {
        return f64::NAN;
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).expect("NaN score"));
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j < n && scores[idx[j]] == scores[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j + 1) as f64 / 2.0; // 1-based average
        for k in i..j {
            ranks[idx[k]] = avg_rank;
        }
        i = j;
    }
    let pos_rank_sum: f64 = labels
        .iter()
        .zip(&ranks)
        .filter(|(y, _)| **y > 0.5)
        .map(|(_, r)| r)
        .sum();
    (pos_rank_sum - n_pos * (n_pos + 1.0) / 2.0) / (n_pos * n_neg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn perfect_separation() {
        let s = vec![0.1, 0.2, 0.8, 0.9];
        let y = vec![0.0, 0.0, 1.0, 1.0];
        assert_eq!(recall_at_fpr(&s, &y, 0.0), 1.0);
        assert!((auc(&s, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_scores_auc_half() {
        let mut rng = Rng::new(1);
        let s: Vec<f64> = (0..50_000).map(|_| rng.f64()).collect();
        let y: Vec<f64> = (0..50_000)
            .map(|_| if rng.bernoulli(0.1) { 1.0 } else { 0.0 })
            .collect();
        assert!((auc(&s, &y) - 0.5).abs() < 0.02);
    }

    #[test]
    fn recall_zero_fpr_with_overlap() {
        // Highest score is a negative: recall at FPR=0 must be 0.
        let s = vec![0.95, 0.8, 0.7];
        let y = vec![0.0, 1.0, 1.0];
        assert_eq!(recall_at_fpr(&s, &y, 0.0), 0.0);
    }

    #[test]
    fn recall_increases_with_fpr_budget() {
        let mut rng = Rng::new(2);
        let mut s = vec![];
        let mut y = vec![];
        for _ in 0..20_000 {
            let fraud = rng.bernoulli(0.05);
            y.push(if fraud { 1.0 } else { 0.0 });
            s.push(if fraud { rng.beta(5.0, 2.0) } else { rng.beta(2.0, 5.0) });
        }
        let r1 = recall_at_fpr(&s, &y, 0.01);
        let r5 = recall_at_fpr(&s, &y, 0.05);
        let r20 = recall_at_fpr(&s, &y, 0.2);
        assert!(r1 < r5 && r5 < r20, "{r1} {r5} {r20}");
    }

    #[test]
    fn degenerate_labels() {
        assert_eq!(recall_at_fpr(&[0.5, 0.6], &[0.0, 0.0], 0.1), 0.0);
        assert_eq!(recall_at_fpr(&[0.5, 0.6], &[1.0, 1.0], 0.1), 0.0);
        assert!(auc(&[0.5], &[1.0]).is_nan());
    }

    #[test]
    fn alert_rate_basics() {
        let s = vec![0.1, 0.5, 0.9, 0.95];
        assert_eq!(alert_rate(&s, 0.9), 0.5);
        assert_eq!(alert_rate(&s, 0.0), 1.0);
        assert_eq!(alert_rate(&[], 0.5), 0.0);
    }

    #[test]
    fn prop_monotone_transform_preserves_recall_and_auc() {
        // The paper's key invariant (Section 3.2): quantile mapping is
        // monotone, so Recall@FPR and AUC are unchanged.
        prop::check(60, |g| {
            let n = g.usize(50..500);
            let mut s = Vec::with_capacity(n);
            let mut y = Vec::with_capacity(n);
            for _ in 0..n {
                let fraud = g.bool(0.2);
                y.push(if fraud { 1.0 } else { 0.0 });
                s.push(if fraud {
                    g.f64(0.0..1.0).powf(0.5)
                } else {
                    g.f64(0.0..1.0).powf(2.0)
                });
            }
            // Strictly monotone map: x -> x^3 * 0.5 + 0.2 (order preserved)
            let t: Vec<f64> = s.iter().map(|&x| 0.5 * x.powi(3) + 0.2).collect();
            let (r_a, r_b) = (recall_at_fpr(&s, &y, 0.05), recall_at_fpr(&t, &y, 0.05));
            prop_assert!((r_a - r_b).abs() < 1e-12, "recall changed: {r_a} vs {r_b}");
            let (a_a, a_b) = (auc(&s, &y), auc(&t, &y));
            if a_a.is_nan() {
                prop_assert!(a_b.is_nan(), "auc NaN mismatch");
            } else {
                prop_assert!((a_a - a_b).abs() < 1e-12, "auc changed: {a_a} vs {a_b}");
            }
            Ok(())
        });
    }

    #[test]
    fn tie_groups_handled_atomically() {
        // All scores identical: FPR budget below 100% yields recall 0.
        let s = vec![0.5; 10];
        let y = vec![1.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0];
        assert_eq!(recall_at_fpr(&s, &y, 0.5), 0.0);
        assert_eq!(recall_at_fpr(&s, &y, 1.0), 1.0);
        assert!((auc(&s, &y) - 0.5).abs() < 1e-12);
    }
}
