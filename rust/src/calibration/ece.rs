//! Expected Calibration Error with the ECE_SWEEP^EM estimator
//! (Roelofs et al. [33], paper Table 1).
//!
//! EM = Equal-Mass binning (each bin holds the same number of
//! predictions); SWEEP = choose the largest bin count for which the
//! per-bin empirical positive rates remain monotone non-decreasing in
//! the score. This debiases the classic fixed-width ECE, which is
//! what the paper uses to evaluate Posterior Correction.

/// One calibration bin (exposed for reliability diagrams).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalBin {
    pub mean_score: f64,
    pub positive_rate: f64,
    pub count: usize,
}

/// Equal-mass binning of (score, label) pairs into `b` bins.
/// Input must be sorted by score ascending.
fn equal_mass_bins(sorted: &[(f64, f64)], b: usize) -> Vec<CalBin> {
    let n = sorted.len();
    let mut bins = Vec::with_capacity(b);
    for i in 0..b {
        let lo = i * n / b;
        let hi = (i + 1) * n / b;
        if hi <= lo {
            continue;
        }
        let chunk = &sorted[lo..hi];
        let mean_score = chunk.iter().map(|(s, _)| s).sum::<f64>() / chunk.len() as f64;
        let positive_rate = chunk.iter().map(|(_, y)| y).sum::<f64>() / chunk.len() as f64;
        bins.push(CalBin {
            mean_score,
            positive_rate,
            count: chunk.len(),
        });
    }
    bins
}

fn is_monotone(bins: &[CalBin]) -> bool {
    bins.windows(2).all(|w| w[1].positive_rate >= w[0].positive_rate)
}

/// ECE for a given binning: sum_b (n_b / n) |acc_b - conf_b|.
fn ece_of(bins: &[CalBin], n: usize) -> f64 {
    bins.iter()
        .map(|b| (b.count as f64 / n as f64) * (b.positive_rate - b.mean_score).abs())
        .sum()
}

/// ECE_SWEEP^EM: sweep the equal-mass bin count upward while the bin
/// prevalences stay monotone; return the ECE at the largest monotone
/// bin count. Returns 0.0 for empty input.
pub fn ece_sweep_em(scores: &[f64], labels: &[f64]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    if n == 0 {
        return 0.0;
    }
    let mut pairs: Vec<(f64, f64)> = scores.iter().cloned().zip(labels.iter().cloned()).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("NaN score"));

    let mut best_bins = equal_mass_bins(&pairs, 1);
    let mut b = 2;
    while b <= n {
        let bins = equal_mass_bins(&pairs, b);
        if !is_monotone(&bins) {
            break;
        }
        best_bins = bins;
        b += 1;
    }
    ece_of(&best_bins, n)
}

/// Classic fixed-width ECE with `n_bins` uniform bins (for
/// comparison/ablation; the paper prefers the sweep estimator because
/// this one is biased).
pub fn ece_fixed_width(scores: &[f64], labels: &[f64], n_bins: usize) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    if n == 0 {
        return 0.0;
    }
    let mut sums = vec![(0.0f64, 0.0f64, 0usize); n_bins];
    for (&s, &y) in scores.iter().zip(labels) {
        let b = ((s * n_bins as f64) as usize).min(n_bins - 1);
        sums[b].0 += s;
        sums[b].1 += y;
        sums[b].2 += 1;
    }
    sums.iter()
        .filter(|(_, _, c)| *c > 0)
        .map(|(s, y, c)| {
            let conf = s / *c as f64;
            let acc = y / *c as f64;
            (*c as f64 / n as f64) * (acc - conf).abs()
        })
        .sum()
}

/// Reliability diagram at the sweep-selected equal-mass binning
/// (exposed for the harness output).
pub fn reliability_diagram(scores: &[f64], labels: &[f64], max_bins: usize) -> Vec<CalBin> {
    let mut pairs: Vec<(f64, f64)> = scores.iter().cloned().zip(labels.iter().cloned()).collect();
    if pairs.is_empty() {
        return vec![];
    }
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut best = equal_mass_bins(&pairs, 1);
    let mut b = 2;
    while b <= max_bins.min(pairs.len()) {
        let bins = equal_mass_bins(&pairs, b);
        if !is_monotone(&bins) {
            break;
        }
        best = bins;
        b += 1;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Synthesize labels whose prevalence equals a distortion of the
    /// score: y ~ Bernoulli(g(s)).
    fn synth(n: usize, seed: u64, g: impl Fn(f64) -> f64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut scores = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let s = rng.f64();
            scores.push(s);
            labels.push(if rng.bernoulli(g(s)) { 1.0 } else { 0.0 });
        }
        (scores, labels)
    }

    #[test]
    fn calibrated_model_has_tiny_ece() {
        let (s, y) = synth(100_000, 1, |p| p);
        let e = ece_sweep_em(&s, &y);
        assert!(e < 0.01, "ECE = {e}");
    }

    #[test]
    fn miscalibrated_model_has_large_ece() {
        // Model predicting s but truth is s^3: badly over-confident mid-range.
        let (s, y) = synth(100_000, 2, |p| p.powi(3));
        let e = ece_sweep_em(&s, &y);
        assert!(e > 0.1, "ECE = {e}");
    }

    #[test]
    fn ece_detects_undersampling_bias() {
        // The paper's scenario: scores are biased upward by the prior
        // shift s' = s / (s + beta (1-s)); true prevalence at s' is s.
        let beta = 0.05;
        let (s_true, y) = synth(100_000, 3, |p| p);
        let biased: Vec<f64> = s_true.iter().map(|&s| s / (s + beta * (1.0 - s))).collect();
        let e_biased = ece_sweep_em(&biased, &y);
        let e_true = ece_sweep_em(&s_true, &y);
        assert!(
            e_biased > 10.0 * e_true,
            "biased {e_biased} vs true {e_true}"
        );
    }

    #[test]
    fn sweep_beats_fixed_width_bias_on_calibrated_data() {
        // On perfectly calibrated data both should be small; the sweep
        // estimator must not blow up.
        let (s, y) = synth(50_000, 4, |p| p);
        let sweep = ece_sweep_em(&s, &y);
        let fixed = ece_fixed_width(&s, &y, 15);
        assert!(sweep <= fixed + 0.01, "sweep {sweep} fixed {fixed}");
    }

    #[test]
    fn constant_prediction_gives_zero_sweep_ece_when_matching_prior() {
        // A constant prediction equal to the prior is "calibrated" by
        // the ECE definition (the paper notes ECE=0 is trivially
        // achievable, motivating the Brier complement).
        let n = 10_000;
        let scores = vec![0.3; n];
        let mut rng = Rng::new(5);
        let labels: Vec<f64> = (0..n)
            .map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 })
            .collect();
        let e = ece_sweep_em(&scores, &labels);
        assert!(e < 0.02, "ECE = {e}");
    }

    #[test]
    fn empty_and_single_inputs() {
        assert_eq!(ece_sweep_em(&[], &[]), 0.0);
        assert!((ece_sweep_em(&[0.7], &[1.0]) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn reliability_diagram_monotone() {
        let (s, y) = synth(20_000, 6, |p| p * 0.8);
        let bins = reliability_diagram(&s, &y, 100);
        assert!(!bins.is_empty());
        for w in bins.windows(2) {
            assert!(w[1].positive_rate >= w[0].positive_rate);
        }
        let total: usize = bins.iter().map(|b| b.count).sum();
        assert_eq!(total, 20_000);
    }

    #[test]
    fn fixed_width_empty_bins_skipped() {
        let s = vec![0.05, 0.06, 0.95, 0.94];
        let y = vec![0.0, 0.0, 1.0, 1.0];
        let e = ece_fixed_width(&s, &y, 10);
        assert!(e.is_finite());
    }
}
