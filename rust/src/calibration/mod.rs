//! Calibration & evaluation metrics used by the paper's exhibits:
//! ECE_SWEEP^EM [33] and Brier [7] (Table 1), Wilson intervals [43]
//! (Figs. 4/6 error bars), Recall@FPR and AUC (Section 3.2).

pub mod brier;
pub mod ece;
pub mod recall;
pub mod wilson;

pub use brier::brier;
pub use ece::ece_sweep_em;
pub use recall::{alert_rate, auc, recall_at_fpr};
pub use wilson::wilson_interval;
