//! Brier score (Brier 1950 [7]) — mean squared error of probabilistic
//! predictions. Complements ECE in Table 1: a constant prediction can
//! trivially achieve ECE = 0 but pays in Brier score, so the paper
//! reports both.

/// Brier score: mean (s - y)^2. Lower is better; 0.0 for empty input.
pub fn brier(scores: &[f64], labels: &[f64]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    if scores.is_empty() {
        return 0.0;
    }
    scores
        .iter()
        .zip(labels)
        .map(|(s, y)| (s - y) * (s - y))
        .sum::<f64>()
        / scores.len() as f64
}

/// Murphy decomposition: Brier = reliability - resolution + uncertainty,
/// computed over equal-mass bins. Useful for diagnosing *why* the
/// Posterior Correction helps (it reduces the reliability term).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BrierDecomposition {
    pub reliability: f64,
    pub resolution: f64,
    pub uncertainty: f64,
}

pub fn brier_decomposition(scores: &[f64], labels: &[f64], n_bins: usize) -> BrierDecomposition {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    if n == 0 {
        return BrierDecomposition {
            reliability: 0.0,
            resolution: 0.0,
            uncertainty: 0.0,
        };
    }
    let base: f64 = labels.iter().sum::<f64>() / n as f64;
    let mut pairs: Vec<(f64, f64)> = scores.iter().cloned().zip(labels.iter().cloned()).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("NaN score"));
    let mut reliability = 0.0;
    let mut resolution = 0.0;
    for i in 0..n_bins {
        let lo = i * n / n_bins;
        let hi = (i + 1) * n / n_bins;
        if hi <= lo {
            continue;
        }
        let chunk = &pairs[lo..hi];
        let nb = chunk.len() as f64;
        let conf = chunk.iter().map(|(s, _)| s).sum::<f64>() / nb;
        let prev = chunk.iter().map(|(_, y)| y).sum::<f64>() / nb;
        reliability += nb / n as f64 * (conf - prev) * (conf - prev);
        resolution += nb / n as f64 * (prev - base) * (prev - base);
    }
    BrierDecomposition {
        reliability,
        resolution,
        uncertainty: base * (1.0 - base),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn perfect_predictions_zero() {
        assert_eq!(brier(&[0.0, 1.0, 1.0], &[0.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn worst_predictions_one() {
        assert_eq!(brier(&[1.0, 0.0], &[0.0, 1.0]), 1.0);
    }

    #[test]
    fn constant_half_is_quarter() {
        let s = vec![0.5; 100];
        let y: Vec<f64> = (0..100).map(|i| (i % 2) as f64).collect();
        assert!((brier(&s, &y) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(brier(&[], &[]), 0.0);
    }

    #[test]
    fn calibrated_beats_biased() {
        // The Table 1 mechanism: biased (undersampling-inflated) scores
        // have a worse Brier score than the corrected ones.
        let mut rng = Rng::new(1);
        let beta = 0.05;
        let mut cal = vec![];
        let mut biased = vec![];
        let mut labels = vec![];
        for _ in 0..50_000 {
            let p = rng.f64() * 0.2; // low-score regime like fraud
            cal.push(p);
            biased.push(p / (p + beta * (1.0 - p)));
            labels.push(if rng.bernoulli(p) { 1.0 } else { 0.0 });
        }
        assert!(brier(&cal, &labels) < 0.5 * brier(&biased, &labels));
    }

    #[test]
    fn decomposition_sums_to_brier() {
        let mut rng = Rng::new(2);
        let mut s = vec![];
        let mut y = vec![];
        for _ in 0..20_000 {
            let p = rng.f64();
            s.push(p);
            y.push(if rng.bernoulli((p * 0.7 + 0.1).clamp(0.0, 1.0)) { 1.0 } else { 0.0 });
        }
        let d = brier_decomposition(&s, &y, 50);
        let total = d.reliability - d.resolution + d.uncertainty;
        let direct = brier(&s, &y);
        // Binning makes this approximate; they should agree to ~1e-2.
        assert!((total - direct).abs() < 0.01, "{total} vs {direct}");
    }

    #[test]
    fn decomposition_calibrated_has_low_reliability() {
        let mut rng = Rng::new(3);
        let mut s = vec![];
        let mut y = vec![];
        for _ in 0..50_000 {
            let p = rng.f64();
            s.push(p);
            y.push(if rng.bernoulli(p) { 1.0 } else { 0.0 });
        }
        let d = brier_decomposition(&s, &y, 20);
        assert!(d.reliability < 0.001, "reliability {}", d.reliability);
        assert!(d.resolution > 0.05);
    }
}
