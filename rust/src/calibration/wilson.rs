//! Wilson score interval (Wilson 1927 [43]) — the error bars on the
//! paper's Fig. 4/6 relative-error-per-bin plots.

/// Two-sided Wilson score interval for a binomial proportion.
/// `successes` out of `trials` at z-score `z` (1.96 = 95%).
/// Returns (lo, hi) in [0, 1]. `trials == 0` yields (0, 1).
pub fn wilson_interval(successes: u64, trials: u64, z: f64) -> (f64, f64) {
    if trials == 0 {
        return (0.0, 1.0);
    }
    let n = trials as f64;
    let p = successes as f64 / n;
    let z2 = z * z;
    let denom = 1.0 + z2 / n;
    let center = (p + z2 / (2.0 * n)) / denom;
    let half = z * (p * (1.0 - p) / n + z2 / (4.0 * n * n)).sqrt() / denom;
    ((center - half).max(0.0), (center + half).min(1.0))
}

/// Wilson interval translated to the relative-error-vs-target scale
/// used by Figs. 4 and 6: given an observed bin count out of `total`
/// and the target share, returns (err_lo_pct, err_pct, err_hi_pct)
/// where err = 100 * (observed_share - target) / target.
pub fn relative_error_with_interval(
    bin_count: u64,
    total: u64,
    target_share: f64,
    z: f64,
) -> (f64, f64, f64) {
    let to_err = |share: f64| {
        if target_share <= 0.0 {
            if share > 0.0 {
                f64::INFINITY
            } else {
                0.0
            }
        } else {
            100.0 * (share - target_share) / target_share
        }
    };
    let (lo, hi) = wilson_interval(bin_count, total, z);
    let point = if total == 0 {
        0.0
    } else {
        bin_count as f64 / total as f64
    };
    (to_err(lo), to_err(point), to_err(hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn zero_trials_is_vacuous() {
        assert_eq!(wilson_interval(0, 0, 1.96), (0.0, 1.0));
    }

    #[test]
    fn contains_point_estimate() {
        let (lo, hi) = wilson_interval(30, 100, 1.96);
        assert!(lo < 0.3 && 0.3 < hi);
    }

    #[test]
    fn known_value() {
        // Classic check: 0 successes of 10 at 95% -> hi ~ 0.278.
        let (lo, hi) = wilson_interval(0, 10, 1.96);
        assert_eq!(lo, 0.0);
        assert!((hi - 0.2775).abs() < 0.01, "hi = {hi}");
    }

    #[test]
    fn narrows_with_n() {
        let (lo1, hi1) = wilson_interval(10, 100, 1.96);
        let (lo2, hi2) = wilson_interval(1000, 10_000, 1.96);
        assert!(hi2 - lo2 < hi1 - lo1);
    }

    #[test]
    fn prop_interval_ordered_and_bounded() {
        prop::check(300, |g| {
            let n = g.usize(1..100_000) as u64;
            let k = g.usize(0..(n as usize + 1)) as u64;
            let (lo, hi) = wilson_interval(k, n, 1.96);
            prop_assert!((0.0..=1.0).contains(&lo), "lo {lo}");
            prop_assert!((0.0..=1.0).contains(&hi), "hi {hi}");
            prop_assert!(lo <= hi, "lo {lo} > hi {hi}");
            let p = k as f64 / n as f64;
            prop_assert!(lo <= p + 1e-12 && p <= hi + 1e-12, "p {p} outside [{lo},{hi}]");
            Ok(())
        });
    }

    #[test]
    fn coverage_close_to_nominal() {
        // Monte-Carlo: 95% interval must cover the true p ~95% of runs.
        let mut rng = Rng::new(17);
        let p_true = 0.07;
        let n = 500;
        let trials = 2000;
        let mut covered = 0;
        for _ in 0..trials {
            let k = (0..n).filter(|_| rng.bernoulli(p_true)).count() as u64;
            let (lo, hi) = wilson_interval(k, n as u64, 1.96);
            if lo <= p_true && p_true <= hi {
                covered += 1;
            }
        }
        let cov = covered as f64 / trials as f64;
        assert!(cov > 0.92 && cov < 0.98, "coverage {cov}");
    }

    #[test]
    fn relative_error_scale() {
        // Observed exactly the target share: error 0, interval straddles 0.
        let (lo, mid, hi) = relative_error_with_interval(700, 1000, 0.7, 1.96);
        assert!(mid.abs() < 1e-9);
        assert!(lo < 0.0 && hi > 0.0);
        // All mass in bin when target is 70%: the paper's +43%.
        let (_, err, _) = relative_error_with_interval(1000, 1000, 0.7, 1.96);
        assert!((err - 42.857).abs() < 0.01);
        // Empty bin: -100%.
        let (_, err, _) = relative_error_with_interval(0, 1000, 0.1, 1.96);
        assert_eq!(err, -100.0);
    }

    #[test]
    fn relative_error_zero_target() {
        let (_, err, _) = relative_error_with_interval(5, 100, 0.0, 1.96);
        assert!(err.is_infinite());
        let (_, err, _) = relative_error_with_interval(0, 100, 0.0, 1.96);
        assert_eq!(err, 0.0);
    }
}
