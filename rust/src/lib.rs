//! # MUSE — Multi-Tenant Model Serving With Seamless Model Updates
//!
//! A full reproduction of the MUSE paper (Feedzai, 2026) as a
//! three-layer Rust + JAX + Pallas stack. This crate is the Layer-3
//! coordinator: intent-based routing, the predictor abstraction with
//! its composable score transformations, multi-tenant model-container
//! sharing, and the rolling-deployment control plane. Model inference
//! executes AOT-compiled HLO (JAX + Pallas, built once by
//! `make artifacts`) through the PJRT CPU client — Python is never on
//! the request path.
//!
//! See DESIGN.md for the system inventory and the experiment index
//! mapping every paper table/figure to a module and harness.

pub mod baselines;
pub mod calibration;
pub mod config;
pub mod coordinator;
pub mod datalake;
pub mod featurestore;
pub mod coldstart;
pub mod metrics;
pub mod repro;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod transforms;
pub mod util;
