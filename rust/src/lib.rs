//! # MUSE — Multi-Tenant Model Serving With Seamless Model Updates
//!
//! A full reproduction of the MUSE paper (Feedzai, 2026) as a
//! three-layer Rust + JAX + Pallas stack. This crate is the Layer-3
//! coordinator: intent-based routing, the predictor abstraction with
//! its composable score transformations, multi-tenant model-container
//! sharing, and the rolling-deployment control plane. Model inference
//! executes AOT-compiled HLO (JAX + Pallas, built once by
//! `make artifacts`) through the PJRT CPU client — Python is never on
//! the request path.
//!
//! The serving split follows the paper's Section 2.5: a **data
//! plane** whose hot path ([`coordinator::Engine::score`]) performs
//! exactly one wait-free snapshot load — no locks, no map probes, no
//! per-request name allocation — and a **control plane**
//! ([`coordinator::ControlPlane`]) that publishes new
//! [`coordinator::EngineSnapshot`]s copy-on-write for every
//! deployment, promotion, decommission and quantile refit. The
//! snapshot primitive itself is [`util::swap::SnapCell`].
//!
//! See `docs/ARCHITECTURE.md` for the system inventory, trust
//! boundaries, request lifecycle and the snapshot-publication
//! protocol, and `EXPERIMENTS.md` for the measurement methodology
//! behind every performance claim in the doc comments.
#![deny(rustdoc::broken_intra_doc_links)]

pub mod baselines;
pub mod calibration;
pub mod cluster;
pub mod coldstart;
pub mod config;
pub mod coordinator;
pub mod datalake;
pub mod featurestore;
pub mod lifecycle;
pub mod metrics;
pub mod repro;
pub mod runtime;
pub mod server;
pub mod simulator;
#[cfg(any(test, feature = "testkit"))]
pub mod testkit;
pub mod transforms;
pub mod util;
