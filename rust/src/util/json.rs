//! A from-scratch JSON parser/serializer (RFC 8259 subset: full syntax,
//! f64 numbers, no surrogate-pair escapes beyond the BMP requirement).
//!
//! Built because the offline crate universe has no `serde_json` (see
//! docs/ARCHITECTURE.md "Crate-availability constraint"). Used for the artifact
//! manifest, expert weight files, the HTTP API payloads and the
//! experiment-harness outputs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization
/// is deterministic (useful for golden tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse or access error with byte-offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------------
    // Typed accessors
    // ---------------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` with a descriptive error for required fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing required field '{key}'"),
            offset: 0,
        })
    }

    /// Convenience: required string field.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?.as_str().ok_or_else(|| JsonError {
            msg: format!("field '{key}' is not a string"),
            offset: 0,
        })
    }

    /// Convenience: required number field.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?.as_f64().ok_or_else(|| JsonError {
            msg: format!("field '{key}' is not a number"),
            offset: 0,
        })
    }

    /// Extract a `Vec<f64>` from an array of numbers.
    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    /// Extract a `Vec<f32>` from an array of numbers.
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|f| f as f32))
            .collect()
    }

    // ---------------------------------------------------------------
    // Builders (ergonomics for harness output)
    // ---------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Num(*v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---------------------------------------------------------------
    // Serialization
    // ---------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = |out: &mut String, n: usize| {
            for _ in 0..n {
                out.push_str("  ");
            }
        };
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    pad(out, indent + 1);
                    v.write_pretty(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    pad(out, indent + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < o.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

/// Streaming-writer building block: append one JSON number to `out`
/// with exactly the formatting `Json::Num` serializes with (integers
/// without a fraction, `null` for non-finite). Lets high-cardinality
/// endpoints (`/metrics` at 100k tenant keys) write straight into the
/// response buffer instead of materializing a `Json` tree.
pub fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; emit null like most tolerant encoders.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

/// Streaming-writer building block: append one JSON string (quoted,
/// escaped) to `out` — the same escaping `Json::Str` serializes with.
pub fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// -----------------------------------------------------------------------
// Parser
// -----------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content after JSON value"));
    }
    Ok(v)
}

/// Parse exactly one JSON value starting at byte `start` of `bytes`
/// (the caller has already positioned `start` on the value's first
/// byte — no leading whitespace is skipped). Returns the value and
/// the byte offset one past its end. Errors carry offsets relative to
/// `bytes`, exactly as [`parse`] would report them — this is the
/// reuse point for the incremental parser in `server::streamjson`,
/// whose differential contract is byte-for-byte error equality with
/// this module.
pub(crate) fn parse_value_at(bytes: &[u8], start: usize) -> Result<(Json, usize), JsonError> {
    let mut p = Parser { bytes, pos: start };
    let v = p.value()?;
    Ok((v, p.pos))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected '{lit}')")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{08}'),
                    Some(b'f') => s.push('\u{0C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // High surrogate: require a low surrogate pair.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // int part
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        // frac
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("invalid fraction"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // exp
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("invalid exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("number out of range"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = parse("\"héllo — 事\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — 事");
    }

    #[test]
    fn rejects_garbage() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "01", "1.", "1e", "\"\\x\"",
            "\"unterminated", "[1] extra", "{\"a\" 1}", "\"\\ud800\"",
        ] {
            assert!(parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true],"nested":{"s":"v\n"},"z":-0.125}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = parse(r#"{"a":[1,2],"b":{"c":"d"}}"#).unwrap();
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"n": 7, "s": "x", "arr": [1.5, 2.5]}"#).unwrap();
        assert_eq!(v.req_f64("n").unwrap(), 7.0);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.get("arr").unwrap().to_f64_vec().unwrap(), vec![1.5, 2.5]);
        assert!(v.req("missing").is_err());
        assert!(v.req_f64("s").is_err());
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..200 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..200 {
            s.push(']');
        }
        assert!(parse(&s).is_ok());
    }
}
