//! Reader for the binary dataset interchange written by
//! `python/compile/datagen.py::write_dataset`.
//!
//! Layout (little endian):
//! `u32 magic "MUSE" | u32 version | u64 n | u32 d | u32 reserved |
//!  f32 features [n*d] row-major | f32 labels [n]`

use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::Read;
use std::path::Path;

pub const DATASET_MAGIC: u32 = 0x4D55_5345; // "MUSE"

/// An in-memory evaluation dataset: features row-major + labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    pub d: usize,
    pub features: Vec<f32>, // n * d, row major
    pub labels: Vec<f32>,   // n, in {0.0, 1.0}
}

impl Dataset {
    pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
        let path = path.as_ref();
        let mut f = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut header = [0u8; 24];
        f.read_exact(&mut header)
            .with_context(|| format!("read header of {}", path.display()))?;
        let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
        let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
        let n = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let d = u32::from_le_bytes(header[16..20].try_into().unwrap()) as usize;
        if magic != DATASET_MAGIC {
            bail!("{}: bad magic {magic:#x}", path.display());
        }
        if version != 1 {
            bail!("{}: unsupported dataset version {version}", path.display());
        }
        if n == 0 || d == 0 || n.checked_mul(d).is_none() {
            bail!("{}: implausible dims n={n} d={d}", path.display());
        }
        let mut feat_bytes = vec![0u8; 4 * n * d];
        f.read_exact(&mut feat_bytes)
            .with_context(|| format!("read features of {}", path.display()))?;
        let mut label_bytes = vec![0u8; 4 * n];
        f.read_exact(&mut label_bytes)
            .with_context(|| format!("read labels of {}", path.display()))?;
        let features = feat_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let labels: Vec<f32> = label_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Dataset { n, d, features, labels })
    }

    /// Row `i` as a feature slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.features[i * self.d..(i + 1) * self.d]
    }

    /// Fraction of positive labels.
    pub fn positive_rate(&self) -> f64 {
        self.labels.iter().map(|&y| y as f64).sum::<f64>() / self.n as f64
    }

    /// A contiguous slice view over rows `[start, start+len)`.
    pub fn rows(&self, start: usize, len: usize) -> &[f32] {
        &self.features[start * self.d..(start + len) * self.d]
    }

    /// Split into (head, tail) views at row `at` (copies).
    pub fn split_at(&self, at: usize) -> (Dataset, Dataset) {
        assert!(at <= self.n);
        let head = Dataset {
            n: at,
            d: self.d,
            features: self.features[..at * self.d].to_vec(),
            labels: self.labels[..at].to_vec(),
        };
        let tail = Dataset {
            n: self.n - at,
            d: self.d,
            features: self.features[at * self.d..].to_vec(),
            labels: self.labels[at..].to_vec(),
        };
        (head, tail)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(n: u64, d: u32, magic: u32, version: u32) -> std::path::PathBuf {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("muse_ds_test_{n}_{d}_{magic}_{version}.bin"));
        let mut f = File::create(&path).unwrap();
        f.write_all(&magic.to_le_bytes()).unwrap();
        f.write_all(&version.to_le_bytes()).unwrap();
        f.write_all(&n.to_le_bytes()).unwrap();
        f.write_all(&d.to_le_bytes()).unwrap();
        f.write_all(&0u32.to_le_bytes()).unwrap();
        for i in 0..(n * d as u64) {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
        for i in 0..n {
            f.write_all(&((i % 2) as f32).to_le_bytes()).unwrap();
        }
        path
    }

    #[test]
    fn roundtrip() {
        let path = write_tmp(6, 3, DATASET_MAGIC, 1);
        let ds = Dataset::load(&path).unwrap();
        assert_eq!((ds.n, ds.d), (6, 3));
        assert_eq!(ds.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(ds.labels, vec![0.0, 1.0, 0.0, 1.0, 0.0, 1.0]);
        assert!((ds.positive_rate() - 0.5).abs() < 1e-12);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = write_tmp(2, 2, 0xDEAD_BEEF, 1);
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_bad_version() {
        let path = write_tmp(2, 2, DATASET_MAGIC, 9);
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir();
        let path = dir.join("muse_ds_trunc.bin");
        std::fs::write(&path, b"short").unwrap();
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn split_at_partitions() {
        let path = write_tmp(10, 2, DATASET_MAGIC, 1);
        let ds = Dataset::load(&path).unwrap();
        let (a, b) = ds.split_at(4);
        assert_eq!((a.n, b.n), (4, 6));
        assert_eq!(a.row(3), ds.row(3));
        assert_eq!(b.row(0), ds.row(4));
        std::fs::remove_file(path).unwrap();
    }
}
