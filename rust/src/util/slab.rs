//! `HandleSlab<V>`: a sharded, slab-indexed registry keyed by dense
//! handles (see `coordinator::tenants::TenantHandle`) — the storage
//! substrate of the tenant state plane.
//!
//! Before this module, every per-tenant structure (interner table,
//! quantile-pipeline slots, `tenant_events` counters, lake pair
//! table, lifecycle feed table) was one map published copy-on-write:
//! the *first touch* of tenant `n` cloned all `n-1` existing entries
//! under the cell's writer lock. Fine at dozens of tenants; an
//! onboarding storm of 100k tenants turns it into an O(n²) republish
//! storm on a single serialized writer. `HandleSlab` keeps the
//! wait-free read contract but makes publication local:
//!
//! * the index space is split across `shards` stripes
//!   (`shard = handle % shards`), so concurrent onboarding threads
//!   publish into different shards instead of one global cell;
//! * each shard is a directory of **lazily allocated fixed-size
//!   segments** (`SEG_SIZE` slots). The directory is a flat array of
//!   `AtomicPtr`s: the first writer into a segment CAS-installs it
//!   (the loser frees its allocation — the same idiom as the data
//!   lake's ring segments), so an idle slab costs one pointer per
//!   *possible* segment, not one slot per possible tenant;
//! * a segment's slots are published through one
//!   [`SnapCell`](crate::util::swap::SnapCell): writers clone and
//!   republish **one segment** (`SEG_SIZE` options, constant-size —
//!   independent of how many tenants exist), readers pay one
//!   wait-free snapshot load + one index.
//!
//! The hot-path probe ([`HandleSlab::get`]) is therefore wait-free:
//! one atomic segment-pointer load + one `SnapCell::load` (itself
//! four atomics) + one bounds-checked index. No mutex is ever taken
//! on a read, no matter how cold the slot.
//!
//! Out-of-range and never-published indices read as `None` — exactly
//! the "table doesn't cover this tenant yet, use defaults" semantics
//! the handle-indexed caches already rely on.

use crate::util::swap::SnapCell;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};
use std::sync::Arc;

/// Slots per segment. Publishing a slot clones exactly this many
/// `Option<V>`s — the constant that replaces the old O(tenants) COW.
pub const SEG_SIZE: usize = 256;

/// Default total index capacity (1M handles) — far above the 100k
/// target, while an empty slab allocates only the per-shard pointer
/// directories.
pub const DEFAULT_CAPACITY: usize = 1 << 20;

type Segment<V> = SnapCell<Vec<Option<V>>>;

struct Shard<V> {
    /// Lazily populated segment directory; null until first write.
    segs: Box<[AtomicPtr<Segment<V>>]>,
    /// Segments allocated so far (RSS accounting for the tsunami's
    /// bounded-memory assertion).
    allocated: AtomicUsize,
}

/// A sharded slab of optional values indexed by dense handles.
pub struct HandleSlab<V> {
    shards: Box<[Shard<V>]>,
    _own: PhantomData<Box<Segment<V>>>,
}

impl<V: Clone> HandleSlab<V> {
    /// A slab striped over `shards` shards covering at least
    /// `capacity` indices. `shards` is clamped to ≥ 1; capacity is
    /// rounded up to whole segments per shard.
    pub fn new(shards: usize, capacity: usize) -> HandleSlab<V> {
        let shards = shards.max(1);
        let per_shard = capacity.max(1).div_ceil(shards);
        let max_segs = per_shard.div_ceil(SEG_SIZE).max(1);
        HandleSlab {
            shards: (0..shards)
                .map(|_| Shard {
                    segs: (0..max_segs).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect(),
                    allocated: AtomicUsize::new(0),
                })
                .collect(),
            _own: PhantomData,
        }
    }

    /// Default-capacity constructor (1M indices).
    pub fn with_shards(shards: usize) -> HandleSlab<V> {
        HandleSlab::new(shards, DEFAULT_CAPACITY)
    }

    #[inline]
    fn locate(&self, index: usize) -> (usize, usize, usize) {
        let shard = index % self.shards.len();
        let local = index / self.shards.len();
        (shard, local / SEG_SIZE, local % SEG_SIZE)
    }

    /// The published value at `index` — wait-free (one segment-pointer
    /// load + one `SnapCell` load + one index). `None` for
    /// out-of-capacity, never-touched, or cleared slots.
    #[inline]
    pub fn get(&self, index: usize) -> Option<V> {
        let (s, seg, off) = self.locate(index);
        let shard = &self.shards[s];
        let ptr = shard.segs.get(seg)?.load(Ordering::Acquire);
        if ptr.is_null() {
            return None;
        }
        // SAFETY: a non-null segment pointer was CAS-installed by
        // `segment()` and is freed only in `Drop` (which requires
        // exclusive ownership), so it outlives this shared borrow.
        let cell = unsafe { &*ptr };
        cell.load()[off].clone()
    }

    /// Publish `value` at `index`, replacing any prior value. Clones
    /// and republishes only the owning segment (`SEG_SIZE` slots);
    /// writers to different segments never contend.
    ///
    /// Panics if `index` exceeds the slab's capacity — handle
    /// allocators are expected to size the slab for their index space.
    pub fn set(&self, index: usize, value: V) {
        self.segment(index, |cell, off| {
            cell.rcu(|old| {
                let mut next = old.as_ref().clone();
                next[off] = Some(value);
                (Arc::new(next), ())
            });
        });
    }

    /// Clear the slot at `index`, returning what it held. A cleared
    /// slot reads as `None` again (cold-tier eviction uses this).
    pub fn clear(&self, index: usize) -> Option<V> {
        let (s, seg, off) = self.locate(index);
        let ptr = self.shards[s].segs.get(seg)?.load(Ordering::Acquire);
        if ptr.is_null() {
            return None; // never-touched segment: nothing to clear
        }
        let cell = unsafe { &*ptr };
        cell.rcu(|old| {
            if old[off].is_none() {
                return (Arc::clone(old), None); // no-op publish
            }
            let mut next = old.as_ref().clone();
            let prev = next[off].take();
            (Arc::new(next), prev)
        })
    }

    /// Read the slot, publishing `init()` if it is empty — racing
    /// initializers converge on one value (the segment's writer lock
    /// re-probes before publishing). The counter slab uses this so
    /// every thread lands its increments on the same atomic.
    pub fn get_or_insert_with(&self, index: usize, init: impl FnOnce() -> V) -> V {
        if let Some(v) = self.get(index) {
            return v;
        }
        self.segment(index, |cell, off| {
            cell.rcu(|old| {
                if let Some(v) = &old[off] {
                    return (Arc::clone(old), v.clone());
                }
                let mut next = old.as_ref().clone();
                let v = init();
                next[off] = Some(v.clone());
                (Arc::new(next), v)
            })
        })
    }

    /// Run `f` with the owning segment cell, allocating the segment on
    /// first touch (CAS; the loser frees its allocation).
    fn segment<R>(&self, index: usize, f: impl FnOnce(&Segment<V>, usize) -> R) -> R {
        let (s, seg, off) = self.locate(index);
        let shard = &self.shards[s];
        let slot = shard
            .segs
            .get(seg)
            .unwrap_or_else(|| panic!("HandleSlab index {index} exceeds capacity"));
        let mut ptr = slot.load(Ordering::Acquire);
        if ptr.is_null() {
            let fresh: Box<Segment<V>> =
                Box::new(SnapCell::new(Arc::new(vec![None; SEG_SIZE])));
            let raw = Box::into_raw(fresh);
            match slot.compare_exchange(
                std::ptr::null_mut(),
                raw,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    shard.allocated.fetch_add(1, Ordering::Relaxed);
                    ptr = raw;
                }
                Err(winner) => {
                    // SAFETY: the CAS failed, so `raw` was never
                    // published; we still own it.
                    drop(unsafe { Box::from_raw(raw) });
                    ptr = winner;
                }
            }
        }
        // SAFETY: see `get` — published segments live until Drop.
        f(unsafe { &*ptr }, off)
    }

    /// Visit every occupied slot, shard by shard, segment by segment —
    /// the streaming-iteration primitive behind `/metrics`: no global
    /// clone, one wait-free segment load at a time.
    pub fn for_each(&self, mut f: impl FnMut(usize, &V)) {
        let n = self.shards.len();
        for (s, shard) in self.shards.iter().enumerate() {
            for (seg, slot) in shard.segs.iter().enumerate() {
                let ptr = slot.load(Ordering::Acquire);
                if ptr.is_null() {
                    continue;
                }
                let snap = unsafe { &*ptr }.load();
                for (off, v) in snap.iter().enumerate() {
                    if let Some(v) = v {
                        f((seg * SEG_SIZE + off) * n + s, v);
                    }
                }
            }
        }
    }

    /// Number of shards (stripes) in this slab.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total indices this slab can hold.
    pub fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.segs.len() * SEG_SIZE).sum()
    }

    /// Segments actually allocated — the slab's real memory footprint
    /// grows in `SEG_SIZE` steps, only where handles landed.
    pub fn segments_allocated(&self) -> usize {
        self.shards.iter().map(|s| s.allocated.load(Ordering::Relaxed)).sum()
    }
}

impl<V> Drop for HandleSlab<V> {
    fn drop(&mut self) {
        for shard in self.shards.iter() {
            for slot in shard.segs.iter() {
                let ptr = slot.swap(std::ptr::null_mut(), Ordering::AcqRel);
                if !ptr.is_null() {
                    // SAFETY: exclusive ownership (`&mut self`); every
                    // non-null pointer was Box::into_raw'd by
                    // `segment()` exactly once.
                    drop(unsafe { Box::from_raw(ptr) });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;
    use std::collections::HashMap;

    #[test]
    fn get_set_clear_roundtrip() {
        let slab: HandleSlab<Arc<str>> = HandleSlab::new(4, 4096);
        assert_eq!(slab.get(0), None);
        slab.set(0, Arc::from("a"));
        slab.set(1037, Arc::from("b"));
        assert_eq!(slab.get(0).as_deref(), Some("a"));
        assert_eq!(slab.get(1037).as_deref(), Some("b"));
        assert_eq!(slab.get(1), None);
        // Replacement publishes in place.
        slab.set(0, Arc::from("a2"));
        assert_eq!(slab.get(0).as_deref(), Some("a2"));
        // Clear returns the old value and empties the slot.
        assert_eq!(slab.clear(0).as_deref(), Some("a2"));
        assert_eq!(slab.get(0), None);
        assert_eq!(slab.clear(0), None);
        // Out-of-capacity reads are use-defaults, never panics.
        assert_eq!(slab.get(usize::MAX - 7), None);
    }

    #[test]
    fn segments_allocate_lazily_and_only_where_touched() {
        let slab: HandleSlab<u64> = HandleSlab::new(2, 1 << 16);
        assert_eq!(slab.segments_allocated(), 0);
        slab.set(0, 1); // shard 0, segment 0
        slab.set(1, 2); // shard 1, segment 0
        assert_eq!(slab.segments_allocated(), 2);
        // Another index in an already-allocated segment: no growth.
        slab.set(2, 3);
        assert_eq!(slab.segments_allocated(), 2);
        // A far index allocates exactly one more segment.
        slab.set(2 * SEG_SIZE * 10, 4);
        assert_eq!(slab.segments_allocated(), 3);
        assert!(slab.capacity() >= 1 << 16);
    }

    #[test]
    fn get_or_insert_with_converges_across_threads() {
        let slab: Arc<HandleSlab<Arc<AtomicUsize>>> = Arc::new(HandleSlab::new(4, 1024));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let slab = Arc::clone(&slab);
                std::thread::spawn(move || {
                    for i in 0..64 {
                        let c = slab.get_or_insert_with(i, || Arc::new(AtomicUsize::new(0)));
                        c.fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Every thread's increments landed on one shared value per
        // index — racing initializers converged.
        for i in 0..64 {
            assert_eq!(slab.get(i).unwrap().load(Ordering::Relaxed), 8, "index {i}");
        }
    }

    #[test]
    fn for_each_visits_exactly_the_occupied_slots() {
        let slab: HandleSlab<u64> = HandleSlab::new(3, 1 << 14);
        let indices = [0usize, 1, 2, 7, 300, 301, 999, 5000];
        for &i in &indices {
            slab.set(i, i as u64 * 10);
        }
        slab.clear(301);
        let mut seen = Vec::new();
        slab.for_each(|i, v| seen.push((i, *v)));
        seen.sort_unstable();
        let want: Vec<(usize, u64)> = indices
            .iter()
            .filter(|&&i| i != 301)
            .map(|&i| (i, i as u64 * 10))
            .collect();
        assert_eq!(seen, want);
    }

    /// The satellite equivalence property at the primitive level: a
    /// slab with any shard count behaves exactly like a plain map —
    /// including shard-count 1, which is the old single-cell COW
    /// layout with segment-local publication.
    #[test]
    fn prop_slab_matches_map_oracle_at_any_shard_count() {
        prop::check(24, |g| {
            let shards = *g.pick(&[1usize, 2, 3, 8]);
            let slab: HandleSlab<u64> = HandleSlab::new(shards, 1 << 12);
            let mut oracle: HashMap<usize, u64> = HashMap::new();
            for _ in 0..g.usize(10..200) {
                let i = g.usize(0..2000);
                if g.bool(0.7) {
                    let v = g.u64();
                    slab.set(i, v);
                    oracle.insert(i, v);
                } else {
                    let got = slab.clear(i);
                    let want = oracle.remove(&i);
                    prop_assert!(got == want, "clear({i}): {got:?} vs {want:?}");
                }
                let probe = g.usize(0..2000);
                let got = slab.get(probe);
                let want = oracle.get(&probe).copied();
                prop_assert!(got == want, "get({probe}): {got:?} vs {want:?}");
            }
            // Full-surface equality via streaming iteration.
            let mut seen: HashMap<usize, u64> = HashMap::new();
            slab.for_each(|i, v| {
                seen.insert(i, *v);
            });
            prop_assert!(seen == oracle, "for_each surface diverged");
            Ok(())
        });
    }

    #[test]
    fn concurrent_writers_to_disjoint_indices_lose_nothing() {
        let slab: Arc<HandleSlab<u64>> = Arc::new(HandleSlab::new(4, 1 << 14));
        let per = 512usize;
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let slab = Arc::clone(&slab);
                std::thread::spawn(move || {
                    for i in 0..per {
                        let idx = t * per + i;
                        slab.set(idx, idx as u64);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for idx in 0..8 * per {
            assert_eq!(slab.get(idx), Some(idx as u64), "index {idx}");
        }
    }
}
