//! From-scratch substrates: JSON, RNG, thread pool, datasets, stats,
//! the lock-free snapshot cell, and a mini property-testing framework
//! (see docs/ARCHITECTURE.md "Crate-availability constraint").

pub mod bench;
pub mod dataset;
pub mod json;
pub mod prop;
pub mod rng;
pub mod slab;
pub mod stats;
pub mod swap;
pub mod threadpool;
