//! From-scratch substrates: JSON, RNG, thread pool, datasets, stats,
//! and a mini property-testing framework (see DESIGN.md
//! "Crate-availability constraint").

pub mod bench;
pub mod dataset;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
