//! Minimal property-based testing framework (no `proptest` offline).
//!
//! Provides seeded random case generation with failure-case shrinking
//! for the coordinator invariants (routing determinism, batch
//! conservation, registry refcounts, transform monotonicity). Usage:
//!
//! ```ignore
//! prop::check(256, |g| {
//!     let xs = g.vec_f64(0.0..1.0, 1..100);
//!     let beta = g.f64(0.01..1.0);
//!     // ... assert invariant, return Ok(()) or Err(msg)
//!     Ok(())
//! });
//! ```

use super::rng::Rng;
use std::ops::Range;

/// Random case generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Shrink pressure in [0,1]: 0 = full-size cases, 1 = minimal.
    shrink: f64,
}

impl Gen {
    fn new(seed: u64, shrink: f64) -> Self {
        Gen { rng: Rng::new(seed), shrink }
    }

    pub fn f64(&mut self, range: Range<f64>) -> f64 {
        // Under shrink pressure, bias towards the low end of the range.
        let u = self.rng.f64() * (1.0 - self.shrink * 0.9);
        range.start + (range.end - range.start) * u
    }

    pub fn usize(&mut self, range: Range<usize>) -> usize {
        assert!(range.end > range.start);
        let span = range.end - range.start;
        let scaled = ((span as f64) * (1.0 - self.shrink * 0.9)).ceil().max(1.0) as usize;
        range.start + self.rng.below(scaled.min(span))
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.bernoulli(p_true)
    }

    pub fn vec_f64(&mut self, each: Range<f64>, len: Range<usize>) -> Vec<f64> {
        let n = self.usize(len);
        (0..n).map(|_| self.f64(each.clone())).collect()
    }

    /// Strictly increasing grid of `n` values spanning [lo, hi].
    pub fn monotone_grid(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        assert!(n >= 2);
        let mut cuts: Vec<f64> = (0..n - 2).map(|_| self.rng.range(lo, hi)).collect();
        cuts.push(lo);
        cuts.push(hi);
        cuts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Enforce strict monotonicity by nudging duplicates.
        for i in 1..cuts.len() {
            if cuts[i] <= cuts[i - 1] {
                cuts[i] = f64::from_bits(cuts[i - 1].to_bits() + 1);
            }
        }
        cuts
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len())]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Result of a property over one generated case.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `prop`. On failure, re-run the failing
/// seed under increasing shrink pressure to report a smaller case,
/// then panic with the seed (re-runnable) and message.
pub fn check<F: Fn(&mut Gen) -> PropResult>(cases: u64, prop: F) {
    check_seeded(0x4D55_5345, cases, prop)
}

/// As `check`, with an explicit base seed (to reproduce failures).
pub fn check_seeded<F: Fn(&mut Gen) -> PropResult>(base_seed: u64, cases: u64, prop: F) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed, 0.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink: same seed, increasing pressure; keep the last failure.
            let mut best = (0.0, msg);
            for step in 1..=8 {
                let pressure = step as f64 / 8.0;
                let mut g = Gen::new(seed, pressure);
                if let Err(m) = prop(&mut g) {
                    best = (pressure, m);
                }
            }
            panic!(
                "property failed (seed={seed:#x}, case={case}, shrink={}): {}",
                best.0, best.1
            );
        }
    }
}

/// Assert helper producing `PropResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(64, |g| {
            let x = g.f64(0.0..1.0);
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(64, |g| {
            let x = g.f64(0.0..1.0);
            prop_assert!(x < 0.5, "x too big: {x}");
            Ok(())
        });
    }

    #[test]
    fn monotone_grid_is_strict() {
        check(64, |g| {
            let n = g.usize(2..50);
            let grid = g.monotone_grid(n, 0.0, 1.0);
            prop_assert!(grid.len() == n, "len");
            prop_assert!(grid[0] == 0.0 && grid[n - 1] == 1.0, "endpoints");
            for w in grid.windows(2) {
                prop_assert!(w[1] > w[0], "not strictly increasing");
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Gen::new(99, 0.0);
        let mut b = Gen::new(99, 0.0);
        assert_eq!(a.vec_f64(0.0..1.0, 5..6), b.vec_f64(0.0..1.0, 5..6));
    }
}
