//! Micro-benchmark harness (no criterion offline): warm-up + timed
//! iterations with mean/percentile reporting, used by the
//! `cargo bench` targets (`harness = false`), plus panic-safe
//! liveness counting for multi-threaded storm drivers.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Decrements the counter on drop. Storm drivers (a control-plane
/// thread looping "while workers are live") count workers with this
/// so a panicking worker still releases the loop instead of
/// deadlocking the scope join behind a spinning peer.
pub struct CountdownGuard<'a>(pub &'a AtomicU64);

impl Drop for CountdownGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub throughput_per_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12.1} ns/iter  p50 {:>10.1}  p99 {:>10.1}  {:>14.0} ops/s",
            self.name, self.mean_ns, self.p50_ns, self.p99_ns, self.throughput_per_s
        )
    }
}

/// Time `f` over `iters` iterations (after `warmup` un-timed ones),
/// sampling per-iteration latency in batches of `batch` calls.
pub fn bench<F: FnMut()>(name: &str, warmup: u64, iters: u64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // Sample in up to 256 batches to keep timer overhead negligible.
    let samples = 256u64.min(iters);
    let batch = (iters / samples).max(1);
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples as usize);
    let total_start = Instant::now();
    let mut done = 0u64;
    while done < iters {
        let n = batch.min(iters - done);
        let t0 = Instant::now();
        for _ in 0..n {
            f();
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / n as f64);
        done += n;
    }
    let wall = total_start.elapsed().as_secs_f64();
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean_ns = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
    let pct = |p: f64| per_iter[((p * (per_iter.len() - 1) as f64) as usize).min(per_iter.len() - 1)];
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns,
        p50_ns: pct(0.50),
        p99_ns: pct(0.99),
        throughput_per_s: iters as f64 / wall,
    }
}

/// Print a section header for a bench binary.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let r = bench("noop-ish", 10, 1000, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert_eq!(r.iters, 1000);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
        assert!(r.report().contains("noop-ish"));
    }
}
