//! Small statistics toolbox shared by transforms, calibration and the
//! experiment harnesses: empirical quantiles, moments, KS distance,
//! histogram binning.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (0.0 for n < 2).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// r-th raw moment: E[x^r].
pub fn raw_moment(xs: &[f64], r: u32) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|x| x.powi(r as i32)).sum::<f64>() / xs.len() as f64
}

/// Empirical quantile at probability `p` (linear interpolation, the
/// "type 7" estimator) over an already **sorted** slice.
pub fn quantile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let p = p.clamp(0.0, 1.0);
    let h = p * (sorted.len() - 1) as f64;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let t = h - lo as f64;
        sorted[lo] * (1.0 - t) + sorted[hi] * t
    }
}

/// Evaluate quantiles at a probability grid over unsorted data.
pub fn quantiles(xs: &[f64], probs: &[f64]) -> Vec<f64> {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in quantile input"));
    probs.iter().map(|&p| quantile_sorted(&sorted, p)).collect()
}

/// Uniform probability grid with `n_points` points: 0, 1/(n-1), ..., 1.
pub fn prob_grid(n_points: usize) -> Vec<f64> {
    assert!(n_points >= 2);
    (0..n_points)
        .map(|i| i as f64 / (n_points - 1) as f64)
        .collect()
}

/// Kolmogorov-Smirnov distance between an empirical sample and a CDF.
pub fn ks_distance(xs: &[f64], cdf: impl Fn(f64) -> f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len() as f64;
    let mut d: f64 = 0.0;
    for (i, &x) in sorted.iter().enumerate() {
        let f = cdf(x);
        let lo = i as f64 / n;
        let hi = (i + 1) as f64 / n;
        d = d.max((f - lo).abs()).max((hi - f).abs());
    }
    d
}

/// Count of samples per uniform bin over [0, 1]; the last bin is
/// closed ([0.9, 1.0] in the paper's 10-bin figures).
pub fn bin_counts(xs: &[f64], n_bins: usize) -> Vec<u64> {
    let mut counts = vec![0u64; n_bins];
    for &x in xs {
        let mut b = (x * n_bins as f64).floor() as isize;
        if b < 0 {
            b = 0;
        }
        if b >= n_bins as isize {
            b = n_bins as isize - 1;
        }
        counts[b as usize] += 1;
    }
    counts
}

/// Relative error of observed bin shares vs target shares, in percent:
/// `100 * (obs - target) / target`. Bins with zero target mass yield
/// `f64::INFINITY` when observed mass is non-zero and 0.0 otherwise.
pub fn relative_error_pct(observed: &[u64], target_shares: &[f64]) -> Vec<f64> {
    assert_eq!(observed.len(), target_shares.len());
    let total: u64 = observed.iter().sum();
    observed
        .iter()
        .zip(target_shares)
        .map(|(&o, &t)| {
            let share = if total == 0 { 0.0 } else { o as f64 / total as f64 };
            if t <= 0.0 {
                if share > 0.0 {
                    f64::INFINITY
                } else {
                    0.0
                }
            } else {
                100.0 * (share - t) / t
            }
        })
        .collect()
}

/// Pearson correlation coefficient.
pub fn correlation(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx * dy).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[1.0]), 0.0);
    }

    #[test]
    fn raw_moments() {
        let xs = [0.5, 0.5];
        assert!((raw_moment(&xs, 1) - 0.5).abs() < 1e-12);
        assert!((raw_moment(&xs, 2) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates() {
        let s = [0.0, 1.0, 2.0, 3.0];
        assert_eq!(quantile_sorted(&s, 0.0), 0.0);
        assert_eq!(quantile_sorted(&s, 1.0), 3.0);
        assert!((quantile_sorted(&s, 0.5) - 1.5).abs() < 1e-12);
        assert!((quantile_sorted(&s, 1.0 / 3.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn quantiles_unsorted_input() {
        let q = quantiles(&[3.0, 1.0, 2.0, 0.0], &[0.0, 0.5, 1.0]);
        assert_eq!(q, vec![0.0, 1.5, 3.0]);
    }

    #[test]
    fn prob_grid_endpoints() {
        let g = prob_grid(5);
        assert_eq!(g, vec![0.0, 0.25, 0.5, 0.75, 1.0]);
    }

    #[test]
    fn ks_uniform_sample_small() {
        // Deterministic uniform grid has tiny KS distance vs U(0,1).
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64 + 0.5) / 1000.0).collect();
        assert!(ks_distance(&xs, |x| x) < 0.001);
    }

    #[test]
    fn ks_detects_mismatch() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i as f64 + 0.5) / 1000.0).powi(2)).collect();
        assert!(ks_distance(&xs, |x| x) > 0.2);
    }

    #[test]
    fn bins_include_right_edge() {
        let c = bin_counts(&[0.0, 0.05, 0.95, 1.0], 10);
        assert_eq!(c[0], 2);
        assert_eq!(c[9], 2);
        assert_eq!(c.iter().sum::<u64>(), 4);
    }

    #[test]
    fn relative_error_basics() {
        let err = relative_error_pct(&[70, 30], &[0.5, 0.5]);
        assert!((err[0] - 40.0).abs() < 1e-9);
        assert!((err[1] + 40.0).abs() < 1e-9);
    }

    #[test]
    fn relative_error_minus_100_for_empty_bins() {
        let err = relative_error_pct(&[100, 0], &[0.7, 0.3]);
        assert!((err[1] + 100.0).abs() < 1e-9);
    }

    #[test]
    fn correlation_signs() {
        let xs = [1.0, 2.0, 3.0];
        assert!((correlation(&xs, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((correlation(&xs, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
    }
}
