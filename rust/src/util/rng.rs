//! Deterministic PRNG + sampling substrate (no `rand` crate offline).
//!
//! xoshiro256++ core (Blackman & Vigna) with splitmix64 seeding, plus
//! the distributions the repo needs: uniform, normal (Ziggurat-free
//! Box-Muller with caching), log-normal, exponential, Beta (Cheng's
//! algorithms BB/BC via Gamma), Bernoulli, and shuffling.

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (for per-thread / per-tenant rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased enough for
    /// simulation purposes via rejection).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let hi = ((x as u128 * n as u128) >> 64) as u64;
            let lo = x.wrapping_mul(n);
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.cached_normal = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / rate
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang (2000); shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: G(a) = G(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.f64().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Beta(a, b) via two Gammas.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        let s = x + y;
        if s == 0.0 {
            0.5
        } else {
            x / s
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let (mut sum, mut sq, mut cube) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
            cube += x * x * x;
        }
        assert!((sum / n as f64).abs() < 0.01);
        assert!((sq / n as f64 - 1.0).abs() < 0.02);
        assert!((cube / n as f64).abs() < 0.05); // symmetry
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(5);
        for shape in [0.5, 1.0, 2.5, 9.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.08 * shape.max(1.0), "shape {shape} mean {mean}");
        }
    }

    #[test]
    fn beta_moments() {
        let mut r = Rng::new(6);
        let (a, b) = (2.0, 5.0);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.beta(a, b)).sum::<f64>() / n as f64;
        assert!((mean - a / (a + b)).abs() < 0.005, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(8);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(9);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.015)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.015).abs() < 0.002, "rate {rate}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 30);
        assert!(d.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(12);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
