//! A fixed-size thread pool (no tokio in the offline crate universe).
//!
//! The serving front end and the dynamic batcher dispatch work through
//! this pool; it supports fire-and-forget jobs, fan-out/join scopes,
//! and graceful shutdown. Deliberately simple: an `mpsc` channel feeds
//! worker threads; the hot path never allocates beyond the boxed job.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    shared: Arc<Mutex<mpsc::Receiver<Msg>>>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<InFlight>,
}

struct InFlight {
    count: AtomicUsize,
    zero: Condvar,
    lock: Mutex<()>,
}

impl ThreadPool {
    /// Spawn `size` workers (>= 1).
    pub fn new(size: usize) -> Self {
        assert!(size >= 1, "thread pool must have at least one worker");
        let (tx, rx) = mpsc::channel::<Msg>();
        let shared = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(InFlight {
            count: AtomicUsize::new(0),
            zero: Condvar::new(),
            lock: Mutex::new(()),
        });
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&shared);
            let fl = Arc::clone(&in_flight);
            workers.push(
                thread::Builder::new()
                    .name(format!("muse-worker-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().expect("pool receiver poisoned");
                            guard.recv()
                        };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                job();
                                if fl.count.fetch_sub(1, Ordering::AcqRel) == 1 {
                                    let _g = fl.lock.lock().unwrap();
                                    fl.zero.notify_all();
                                }
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("failed to spawn worker"),
            );
        }
        ThreadPool { tx, shared, workers, in_flight }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.count.fetch_add(1, Ordering::AcqRel);
        self.tx
            .send(Msg::Run(Box::new(f)))
            .expect("thread pool has shut down");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.in_flight.lock.lock().unwrap();
        while self.in_flight.count.load(Ordering::Acquire) != 0 {
            guard = self.in_flight.zero.wait(guard).unwrap();
        }
    }

    /// Run `f` over every item of `items` in parallel, collecting the
    /// results in input order. Blocks until all complete.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let (done_tx, done_rx) = mpsc::channel::<()>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            let done = done_tx.clone();
            self.execute(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
                let _ = done.send(());
            });
        }
        drop(done_tx);
        for _ in 0..n {
            done_rx.recv().expect("worker dropped result channel");
        }
        // Workers may still hold their Arc clone for an instant after
        // signalling completion, so take the results through the lock
        // rather than unwrapping the Arc.
        let mut guard = results.lock().unwrap();
        guard
            .iter_mut()
            .map(|o| o.take().expect("missing map result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        // Nudge any worker stuck between recv() calls.
        let _ = self.shared; // keep the receiver alive until joins finish
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..100).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn map_with_slow_jobs() {
        let pool = ThreadPool::new(4);
        let out = pool.map(vec![30u64, 1, 20, 2], |ms| {
            thread::sleep(Duration::from_millis(ms));
            ms
        });
        assert_eq!(out, vec![30, 1, 20, 2]);
    }

    #[test]
    fn wait_idle_without_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                thread::sleep(Duration::from_millis(5));
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 10);
    }

    #[test]
    #[should_panic]
    fn zero_workers_panics() {
        let _ = ThreadPool::new(0);
    }
}
