//! `SnapCell<T>`: an atomically swappable, lock-free-on-read snapshot
//! holder (an `ArcSwap`-style epoch pointer built on `AtomicPtr` +
//! `Arc` — no external crates).
//!
//! Readers (`load`) never block and never touch a mutex: one counter
//! increment, one pointer load, one refcount increment, one counter
//! decrement — wait-free on every path. (Readers do share the
//! `inflight` counter's cache line, so a load is not *contention*-
//! free; what it can never do is wait on a writer, which is the
//! failure mode that makes `RwLock` readers collapse under a swap
//! storm — see EXPERIMENTS.md "Contention".) Writers (`store` /
//! `rcu`) serialize on an internal mutex, which is exactly the MUSE
//! split: the data plane reads snapshots at request rate, the
//! control plane publishes new ones at deployment rate (paper
//! Section 2.5).
//!
//! # Memory reclamation
//!
//! The classic hazard of `AtomicPtr<ArcInner>` schemes is a reader
//! incrementing the strong count of an allocation a concurrent writer
//! just freed. `SnapCell` closes that window with a keep-alive list
//! plus a quiescence gate:
//!
//! * every `Arc` ever published is retained in a writer-side
//!   keep-alive list, so any pointer a reader can observe refers to a
//!   live allocation (strong count >= 1) for as long as it is
//!   reachable;
//! * reclamation runs only on the write path, and only after the
//!   writer observes `inflight == 0` — i.e. no reader is inside the
//!   load()-to-refcount-increment window. Readers entering after that
//!   observation can only see the freshly published pointer
//!   (everything is `SeqCst`, so the publish store precedes the
//!   quiescence check in the total order), never a retired one.
//!
//! Retired snapshots therefore persist at most until the next write
//! that observes a quiescent moment; the load window is a handful of
//! instructions, so in practice the keep-alive list stays at O(1).
//! Worst case it is bounded by the number of control-plane swaps —
//! O(deployments), never O(requests).

use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A lock-free-on-read cell holding an immutable snapshot `Arc<T>`.
pub struct SnapCell<T> {
    /// Raw view of the currently published snapshot. Does **not** own
    /// a strong count: validity is guaranteed by `keepalive`.
    current: AtomicPtr<T>,
    /// Number of readers inside the load()-to-increment window.
    inflight: AtomicUsize,
    /// Every published `Arc` not yet proven unreachable. Doubles as
    /// the writer lock: all publications serialize on it.
    keepalive: Mutex<Vec<Arc<T>>>,
}

impl<T> SnapCell<T> {
    pub fn new(value: Arc<T>) -> SnapCell<T> {
        let ptr = Arc::as_ptr(&value) as *mut T;
        SnapCell {
            current: AtomicPtr::new(ptr),
            inflight: AtomicUsize::new(0),
            keepalive: Mutex::new(vec![value]),
        }
    }

    /// Read the current snapshot. Wait-free: no mutex, no spinning,
    /// no allocation — four atomic operations.
    pub fn load(&self) -> Arc<T> {
        self.inflight.fetch_add(1, Ordering::SeqCst);
        let ptr = self.current.load(Ordering::SeqCst);
        // SAFETY: `ptr` was published by `store`/`rcu`, which retains
        // a keep-alive `Arc` for it before publishing. Reclamation
        // (`collect`) frees a retired snapshot only after observing
        // `inflight == 0`; we raised `inflight` before loading `ptr`,
        // so either the collector saw us (and skipped reclaiming) or
        // we loaded the pointer it just published (which is never
        // reclaimed). Hence the allocation is live for the whole
        // window and the increment is sound; `from_raw` adopts the
        // count we just added.
        let arc = unsafe {
            Arc::increment_strong_count(ptr);
            Arc::from_raw(ptr)
        };
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        arc
    }

    /// The raw identity of the current snapshot, for cheap staleness
    /// checks (`ptr == cell.peek()`). Never dereference it.
    pub fn peek(&self) -> *const T {
        self.current.load(Ordering::SeqCst)
    }

    /// Publish a new snapshot (copy-on-write is the caller's job).
    pub fn store(&self, value: Arc<T>) {
        let mut held = self.keepalive.lock().unwrap();
        held.push(Arc::clone(&value));
        self.current.store(Arc::as_ptr(&value) as *mut T, Ordering::SeqCst);
        self.collect(&mut held);
    }

    /// Read-copy-update: compute the next snapshot from the current
    /// one and publish it, all under the writer lock so concurrent
    /// updaters compose instead of clobbering each other. Returning
    /// a clone of the current `Arc` makes the call a no-op publish
    /// (no keep-alive growth) — updaters that discover nothing
    /// changed under the lock use this to avoid republishing
    /// identical snapshots back-to-back. Returns the closure's
    /// side-channel value.
    pub fn rcu<R>(&self, f: impl FnOnce(&Arc<T>) -> (Arc<T>, R)) -> R {
        let mut held = self.keepalive.lock().unwrap();
        let cur_ptr = self.current.load(Ordering::SeqCst) as *const T;
        let cur = held
            .iter()
            .find(|a| Arc::as_ptr(a) == cur_ptr)
            .expect("current snapshot must be in the keep-alive list")
            .clone();
        let (next, out) = f(&cur);
        // Drop the working clone before collecting, or the snapshot
        // we are retiring stays pinned (strong count >= 2) until the
        // *next* write — indefinitely on a quiescent control plane.
        drop(cur);
        if Arc::as_ptr(&next) != cur_ptr {
            held.push(Arc::clone(&next));
            self.current.store(Arc::as_ptr(&next) as *mut T, Ordering::SeqCst);
        }
        self.collect(&mut held);
        out
    }

    /// Drop retired snapshots once no reader can reach them. Runs
    /// under the writer lock. Bounded: gives up if readers keep
    /// streaming through the (nanoseconds-wide) load window; the next
    /// write retries.
    fn collect(&self, held: &mut Vec<Arc<T>>) {
        for _ in 0..16 {
            if self.inflight.load(Ordering::SeqCst) == 0 {
                let cur = self.current.load(Ordering::SeqCst) as *const T;
                // Keep the published snapshot and anything still held
                // by outstanding reader clones; everything else is
                // unreachable (proof in the module docs).
                held.retain(|a| Arc::as_ptr(a) == cur || Arc::strong_count(a) > 1);
                return;
            }
            std::hint::spin_loop();
        }
    }

    /// Number of retired-but-not-yet-reclaimed snapshots (tests and
    /// observability; 0 in a quiescent steady state).
    pub fn retired(&self) -> usize {
        self.keepalive.lock().unwrap().len().saturating_sub(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::thread;

    #[test]
    fn load_returns_latest_store() {
        let cell = SnapCell::new(Arc::new(1u64));
        assert_eq!(*cell.load(), 1);
        cell.store(Arc::new(2));
        assert_eq!(*cell.load(), 2);
        cell.store(Arc::new(3));
        assert_eq!(*cell.load(), 3);
    }

    #[test]
    fn rcu_composes_updates() {
        let cell = SnapCell::new(Arc::new(vec![1u32]));
        let len = cell.rcu(|old| {
            let mut next = old.as_ref().clone();
            next.push(2);
            let n = next.len();
            (Arc::new(next), n)
        });
        assert_eq!(len, 2);
        assert_eq!(*cell.load(), vec![1, 2]);
        // The replaced snapshot must be reclaimed by the same rcu
        // call, not pinned until the next write (decommissioned
        // predictors in a retired EngineSnapshot ride on this).
        assert_eq!(cell.retired(), 0, "rcu must not pin the snapshot it retired");
    }

    #[test]
    fn rcu_same_arc_is_a_no_op_publish() {
        let cell = SnapCell::new(Arc::new(5u64));
        for _ in 0..50 {
            cell.rcu(|old| (Arc::clone(old), ()));
        }
        assert_eq!(*cell.load(), 5);
        assert_eq!(cell.retired(), 0, "no-op rcu must not grow the keep-alive list");
    }

    #[test]
    fn retired_snapshots_are_reclaimed() {
        let cell = SnapCell::new(Arc::new(0u64));
        for i in 1..=100 {
            cell.store(Arc::new(i));
        }
        // Quiescent writer: every retired snapshot must have been
        // collected on some store.
        assert_eq!(cell.retired(), 0, "keep-alive list must not grow");
        // A clone held by a "reader" pins exactly that snapshot.
        let pinned = cell.load();
        cell.store(Arc::new(101));
        assert_eq!(cell.retired(), 1);
        drop(pinned);
        cell.store(Arc::new(102));
        assert_eq!(cell.retired(), 0);
    }

    #[test]
    fn concurrent_readers_see_only_published_values() {
        // Readers hammer load() while a writer publishes a strictly
        // increasing sequence; every observed value must be one that
        // was published, and per-reader observations must be monotone
        // (snapshots can be stale but never torn or reordered).
        let cell = Arc::new(SnapCell::new(Arc::new(0u64)));
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let cell = Arc::clone(&cell);
                let stop = Arc::clone(&stop);
                thread::spawn(move || {
                    let mut last = 0u64;
                    let mut n = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let v = *cell.load();
                        assert!(v >= last, "went backwards: {v} < {last}");
                        last = v;
                        n += 1;
                    }
                    n
                })
            })
            .collect();
        for i in 1..=10_000u64 {
            cell.store(Arc::new(i));
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            assert!(r.join().unwrap() > 0);
        }
        assert_eq!(*cell.load(), 10_000);
    }

    #[test]
    fn concurrent_rcu_writers_never_lose_updates() {
        let cell = Arc::new(SnapCell::new(Arc::new(0u64)));
        thread::scope(|s| {
            for _ in 0..4 {
                let cell = &cell;
                s.spawn(move || {
                    for _ in 0..1_000 {
                        cell.rcu(|old| (Arc::new(**old + 1), ()));
                    }
                });
            }
        });
        assert_eq!(*cell.load(), 4_000, "rcu must serialize increments");
    }
}
