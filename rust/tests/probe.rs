//! Cross-language numeric integration test: the python compile path
//! writes a probe batch plus its expected scores per expert
//! (`artifacts/probe.json`); every PJRT container must reproduce them.
//! This is the guard against interchange bugs (e.g. HLO-text constant
//! elision silently zeroing baked weights).

use muse::runtime::{Manifest, ModelPool};
use muse::util::json;
use std::sync::Arc;

#[test]
fn containers_match_python_oracle() {
    let root = Manifest::default_root();
    let probe_path = root.join("probe.json");
    if !probe_path.exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = Manifest::load(&root).unwrap();
    let pool = Arc::new(ModelPool::new(manifest));
    let probe = json::parse(&std::fs::read_to_string(probe_path).unwrap()).unwrap();
    let features = probe.req("features").unwrap().to_f32_vec().unwrap();
    let n = probe.req_f64("n").unwrap() as usize;
    let expected = probe.req("expected").unwrap().as_obj().unwrap();
    assert!(!expected.is_empty());
    for (model, exp) in expected {
        let exp = exp.to_f64_vec().unwrap();
        let handle = pool.acquire(model).unwrap();
        let got = handle.infer(&features, n).unwrap();
        assert_eq!(got.len(), exp.len());
        let mut distinct = false;
        for (g, e) in got.iter().zip(&exp) {
            assert!(
                (*g as f64 - e).abs() < 2e-4,
                "model {model}: rust {g} vs python {e}"
            );
        }
        for w in got.windows(2) {
            if (w[0] - w[1]).abs() > 1e-6 {
                distinct = true;
            }
        }
        assert!(distinct, "model {model}: constant output (weights lost?)");
    }
}
