//! Repro-plane smoke suite: every paper-exhibit harness must run end
//! to end on the synthetic paper-roster fixture
//! (`SimArtifacts::in_temp_paper`) with **no** `make artifacts`, no
//! Python, no network.
//!
//! This asserts *executability*, not paper fidelity: the shape checks
//! inside each harness print `[ok]`/`[FAIL]` lines either way, and
//! only the real AOT artifacts reproduce the paper's exact figures
//! (docs/TESTING.md "Repro smoke"). What rots without this suite is
//! the harness plumbing itself — manifest/dataset wiring, the fit
//! paths, engine construction — which used to be exercised only on
//! machines that had run the full Python compile step.
//!
//! Kept as a single `#[test]` on purpose: the harnesses resolve the
//! artifact root through the `MUSE_ARTIFACTS` environment variable,
//! and this file being its own integration-test binary (plus one test
//! function) means the `set_var` cannot race another test's
//! `Manifest::default_root` lookup.

use muse::repro;
use muse::runtime::SimArtifacts;

#[test]
fn every_repro_harness_runs_on_synthetic_artifacts() {
    let fix = SimArtifacts::in_temp_paper().expect("paper fixture");
    std::env::set_var("MUSE_ARTIFACTS", fix.root());

    // Fig. 5 is pure cluster simulation (no artifacts) — and its shape
    // checks are deterministic, so they must pass even here.
    let out = repro::fig5::run().expect("fig5");
    assert!(out.contains("Figure 5"), "{out}");
    assert!(!out.contains("[FAIL]"), "fig5 shape must hold:\n{out}");

    // The artifact-backed harnesses: end-to-end completion on the
    // synthetic roster (cold-start mixture fit, quantile fits, recall,
    // calibration tables, SLO measurement).
    let out = repro::fig4::run().expect("fig4");
    assert!(out.contains("Figure 4"), "{out}");
    assert!(out.contains("predictor v1"), "{out}");

    let out = repro::fig6::run().expect("fig6");
    assert!(out.contains("Figure 6"), "{out}");
    assert!(out.contains("Recall@1%FPR"), "{out}");

    let out = repro::table1::run().expect("table1");
    assert!(out.contains("Table 1"), "{out}");
    assert!(out.contains("Brier"), "{out}");

    // Headline at reduced volume (full volume is `muse repro
    // headline`); debug builds only require completion, mirroring the
    // harness's own in-tree test.
    let out = repro::headline::run_scaled(4, 400).expect("headline");
    assert!(out.contains("throughput"), "{out}");
}
