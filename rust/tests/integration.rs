//! Cross-module integration tests: config -> engine -> HTTP serving ->
//! lifecycle operations -> teardown, plus failure injection. These
//! exercise the same composition the examples and the production CLI
//! use. Tests needing AOT artifacts skip politely when absent.

use muse::config::{Intent, MuseConfig, PredictorConfig, QuantileMode};
use muse::coordinator::{ControlPlane, Engine, ScoreRequest};
use muse::runtime::{Manifest, ModelPool};
use muse::server::http::http_request;
use muse::simulator::{TenantProfile, Workload};
use muse::transforms::{QuantileMap, ReferenceDistribution};
use std::sync::Arc;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "p1"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "global"
  shadowRules:
  - description: "bank1 shadow"
    condition:
      tenants: ["bank1"]
    targetPredictorNames: ["p2"]
predictors:
- name: p1
  experts: [m1, m2]
  quantile: identity
- name: p2
  experts: [m1, m2, m3]
  quantile: identity
- name: global
  experts: [m1]
  quantile: identity
server:
  workers: 4
"#;

fn engine() -> Option<Arc<Engine>> {
    let root = Manifest::default_root();
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let pool = Arc::new(ModelPool::new(Manifest::load(root).unwrap()));
    Some(Arc::new(
        Engine::build(&MuseConfig::from_yaml(CONFIG).unwrap(), pool).unwrap(),
    ))
}

fn drive(engine: &Engine, tenant: &str, n: usize, seed: u64) {
    let mut wl = Workload::new(TenantProfile::new(tenant, seed, 0.4, 0.2), seed);
    for i in 0..n {
        let e = wl.next_event();
        engine
            .score(&ScoreRequest {
                intent: Intent {
                    tenant: tenant.into(),
                    ..Intent::default()
                },
                entity: format!("{tenant}-{i}"),
                features: e.features,
            })
            .unwrap();
    }
    engine.drain_shadows();
}

#[test]
fn full_stack_http_and_lifecycle() {
    let Some(engine) = engine() else { return };
    // Phase 1: serve over HTTP with warm-up gating.
    let (addr, _ready, _h) =
        muse::server::spawn_server(Arc::clone(&engine), "127.0.0.1:0", 4, 50).unwrap();
    let d = engine.predictor("p1").unwrap().feature_dim();
    let feats: Vec<String> = (0..d).map(|i| format!("{}", i as f32 * 0.01)).collect();
    let payload = format!(r#"{{"tenant":"bank1","features":[{}]}}"#, feats.join(","));
    let (status, body) = http_request(&addr, "POST", "/score", &payload).unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"predictor\":\"p1\""), "{body}");

    // Phase 2: traffic accumulates; promote the shadow; decommission.
    drive(&engine, "bank1", 64, 1);
    let cp = ControlPlane::new(&engine);
    cp.promote("bank1", "p2").unwrap();
    cp.decommission("p1").unwrap();
    let (status, body) = http_request(&addr, "POST", "/score", &payload).unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("\"predictor\":\"p2\""), "{body}");

    // Phase 3: stats reflect the shared-container reality.
    let (_, stats) = http_request(&addr, "GET", "/admin/stats", "").unwrap();
    let v = muse::util::json::parse(&stats).unwrap();
    assert_eq!(v.req_f64("live_containers").unwrap(), 3.0); // m1,m2,m3
    assert_eq!(v.req_f64("predictors").unwrap(), 2.0); // p2 + global
}

#[test]
fn tenant_isolation_of_custom_transforms() {
    let Some(engine) = engine() else { return };
    drive(&engine, "bank1", 32, 2);
    drive(&engine, "otherco", 32, 3);
    let cp = ControlPlane::new(&engine);
    // Install an extreme custom transform for bank1 only.
    cp.install_custom_quantile(
        "p1",
        "bank1",
        QuantileMap::new(vec![0.0, 1.0], vec![0.95, 1.0]).unwrap().shared(),
    )
    .unwrap();
    let p1 = engine.predictor("p1").unwrap();
    let d = p1.feature_dim();
    let x = vec![0.0f32; d];
    let bank1 = p1.score(&x, 1, "bank1").unwrap().scores[0];
    let other = p1.score(&x, 1, "otherco").unwrap().scores[0];
    assert!(bank1 >= 0.95);
    assert!(other < 0.95, "tenant isolation violated: {other}");
}

#[test]
fn shadow_failure_does_not_affect_live_path() {
    let Some(engine) = engine() else { return };
    // Failure injection: tear down the shadow target behind the
    // router's back (the control plane would normally clean the rules
    // up — this simulates a stale/racing config). Live scoring must
    // keep working and the miss must be counted.
    engine.registry.decommission("p2").unwrap(); // bank1's shadow target
    drive(&engine, "bank1", 16, 4);
    assert_eq!(engine.counters.get("shadow_missing_predictor"), 16);
    assert_eq!(engine.lake.raw_scores("bank1", "p1").len(), 16);
}

#[test]
fn eq5_gate_blocks_premature_custom_fit_then_opens() {
    let Some(engine) = engine() else { return };
    let cp = ControlPlane::new(&engine);
    let reference = ReferenceDistribution::fraud_default();
    drive(&engine, "bank1", 100, 5);
    assert!(cp
        .fit_custom_quantile("p1", "bank1", &reference, 0.01, 0.2, 1.96)
        .is_err());
    drive(&engine, "bank1", 1_200, 6);
    // Lax gate (a=0.5) now passes with 1300 samples.
    cp.fit_custom_quantile("p1", "bank1", &reference, 0.5, 0.2, 1.96)
        .unwrap();
    assert!(engine.predictor("p1").unwrap().has_tenant_quantile("bank1"));
}

#[test]
fn batch_scoring_follows_promotions_and_mirrors_shadows() {
    let Some(engine) = engine() else { return };
    let mut wl = Workload::new(TenantProfile::new("bank1", 8, 0.4, 0.2), 8);
    let reqs: Vec<ScoreRequest> = (0..10)
        .map(|i| ScoreRequest {
            intent: Intent {
                tenant: "bank1".into(),
                ..Intent::default()
            },
            entity: format!("b{i}"),
            features: wl.next_event().features,
        })
        .collect();
    // Before promotion: live p1, whole batch mirrored to the shadow p2.
    let before = engine.score_batch(&reqs).unwrap();
    assert!(before
        .iter()
        .all(|r| &*r.predictor == "p1" && r.shadow_count == 1));
    engine.drain_shadows();
    assert_eq!(
        engine.lake.counts()[&("bank1".to_string(), "p2".to_string(), true)],
        10,
        "batch shadows must mirror the whole group"
    );
    // Promote the shadow; the next batch lands on p2, shadow rule gone.
    let cp = ControlPlane::new(&engine);
    cp.promote("bank1", "p2").unwrap();
    let after = engine.score_batch(&reqs).unwrap();
    assert!(after
        .iter()
        .all(|r| &*r.predictor == "p2" && r.shadow_count == 0));
    engine.drain_shadows();
    // Per-tenant accounting is batch-aware across the whole lifecycle.
    assert_eq!(engine.tenant_events.get("bank1"), 20);
    assert_eq!(engine.counters.get("events_batch"), 20);
    assert_eq!(engine.counters.get("requests_batch"), 2);
}

#[test]
fn scoring_unknown_route_errors_cleanly() {
    let Some(engine) = engine() else { return };
    // Remove the catch-all: unknown tenants must get a clean error,
    // not a panic.
    let mut cfg = engine.router.snapshot().as_ref().clone();
    cfg.scoring_rules.retain(|r| !r.condition.is_catch_all());
    engine.router.swap(cfg);
    let d = engine.predictor("p1").unwrap().feature_dim();
    let err = engine
        .score(&ScoreRequest {
            intent: Intent {
                tenant: "stranger".into(),
                ..Intent::default()
            },
            entity: "e".into(),
            features: vec![0.0; d],
        })
        .unwrap_err();
    assert!(err.to_string().contains("no scoring rule"), "{err}");
}

#[test]
fn promotions_under_load_never_drop_requests() {
    // The engine-level swap-under-load proof (paper Sections
    // 2.5.1-2.5.2): worker threads score continuously while the
    // control plane ping-pongs bank1 between p1 and p2. Every request
    // must succeed and land on one of the two predictors — a dropped
    // or stalled request fails the run, a torn snapshot would route
    // to a predictor/batcher mismatch and error.
    let Some(engine) = engine() else { return };
    let d = engine.predictor("p1").unwrap().feature_dim();
    let swaps = std::sync::atomic::AtomicU64::new(0);
    let done = std::sync::atomic::AtomicU64::new(0);
    let workers_live = std::sync::atomic::AtomicU64::new(3);
    std::thread::scope(|s| {
        for w in 0..3u64 {
            let engine = &engine;
            let done = &done;
            let workers_live = &workers_live;
            s.spawn(move || {
                // Panic-safe: a dropped request (the failure this test
                // exists to catch) must release the promotion loop,
                // not hang the scope join until the harness timeout.
                let _live = muse::util::bench::CountdownGuard(workers_live);
                for i in 0..300u64 {
                    let resp = engine
                        .score(&ScoreRequest {
                            intent: Intent {
                                tenant: "bank1".into(),
                                ..Intent::default()
                            },
                            entity: format!("w{w}-{i}"),
                            features: vec![0.01 * (i as f32), 0.2]
                                .into_iter()
                                .cycle()
                                .take(d)
                                .collect(),
                        })
                        .expect("request dropped during promotion storm");
                    assert!(
                        &*resp.predictor == "p1" || &*resp.predictor == "p2",
                        "routed to unexpected predictor {}",
                        resp.predictor
                    );
                    done.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            });
        }
        let engine = &engine;
        let swaps = &swaps;
        let workers_live = &workers_live;
        s.spawn(move || {
            let cp = ControlPlane::new(engine);
            let mut k = 0u64;
            while workers_live.load(std::sync::atomic::Ordering::Relaxed) > 0 {
                let target = if k % 2 == 0 { "p2" } else { "p1" };
                cp.promote("bank1", target).unwrap();
                k += 1;
            }
            swaps.fetch_add(k, std::sync::atomic::Ordering::Relaxed);
        });
    });
    assert_eq!(done.load(std::sync::atomic::Ordering::Relaxed), 900);
    assert!(
        swaps.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "promotion storm never ran"
    );
    engine.drain_shadows();
}

#[test]
fn deploy_teardown_cycles_do_not_leak_containers() {
    let Some(engine) = engine() else { return };
    let cp = ControlPlane::new(&engine);
    let base = engine.registry.stats().pool.live_containers;
    for round in 0..3 {
        let cfg = PredictorConfig {
            name: format!("cycle-{round}"),
            experts: vec!["m4".into(), "m5".into()],
            weights: vec![1.0, 1.0],
            quantile_mode: QuantileMode::Identity,
            reference: "fraud-default".into(),
            posterior_correction: true,
        };
        cp.shadow_deploy(&cfg, "bank1", QuantileMap::identity(33).unwrap().shared())
            .unwrap();
        drive(&engine, "bank1", 8, 100 + round);
        cp.decommission(&format!("cycle-{round}")).unwrap();
    }
    assert_eq!(engine.registry.stats().pool.live_containers, base);
}

// ---------------------------------------------------------------
// Observation-plane concurrency stress (sim-dialect artifacts: runs
// everywhere, including CI, without `make artifacts`).
// ---------------------------------------------------------------

/// Engine over synthetic artifacts with two promotable predictors and
/// a configurable lake geometry.
fn sim_engine(
    lake_max_records: usize,
    lake_shards: usize,
) -> (muse::runtime::SimArtifacts, Arc<Engine>) {
    let fix = muse::runtime::SimArtifacts::in_temp().unwrap();
    let yaml = format!(
        r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "duo"
  - description: "catch-all"
    condition: {{}}
    targetPredictorName: "solo"
predictors:
- name: duo
  experts: [s1, s2]
  quantile: identity
- name: solo
  experts: [s3]
  quantile: identity
server:
  workers: 2
  maxBatchDelayUs: 50
  lakeMaxRecords: {lake_max_records}
  lakeShards: {lake_shards}
"#
    );
    let pool = Arc::new(muse::runtime::ModelPool::new(fix.manifest().unwrap()));
    let engine = Arc::new(Engine::build(&MuseConfig::from_yaml(&yaml).unwrap(), pool).unwrap());
    (fix, engine)
}

#[test]
fn sharded_lake_is_oracle_exact_under_a_swap_storm() {
    // Satellite acceptance: 8 threads hammer score() while the
    // control plane ping-pongs bank1 between two predictors as fast
    // as it can publish snapshots. Every response names the predictor
    // that scored it, so the drivers themselves accumulate a
    // sequential oracle; after quiescence the shard-merged
    // count_for/len must match it exactly.
    let (_fix, engine) = sim_engine(0, 8);
    let per_thread = 400usize;
    let threads = 8usize;
    let workers_live = std::sync::atomic::AtomicU64::new(threads as u64);
    let tallies: std::sync::Mutex<Vec<(String, u64)>> = std::sync::Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for w in 0..threads {
            let engine = &engine;
            let workers_live = &workers_live;
            let tallies = &tallies;
            s.spawn(move || {
                let _live = muse::util::bench::CountdownGuard(workers_live);
                let mut wl = Workload::new(TenantProfile::new("bank1", 60 + w as u64, 0.3, 0.1), 3);
                let mut local: Vec<(String, u64)> = Vec::new();
                for i in 0..per_thread {
                    let e = wl.next_event();
                    let resp = engine
                        .score(&ScoreRequest {
                            intent: Intent {
                                tenant: "bank1".into(),
                                ..Intent::default()
                            },
                            entity: format!("st{w}-{i}"),
                            features: e.features,
                        })
                        .expect("request dropped during storm");
                    let name = resp.predictor.to_string();
                    match local.iter_mut().find(|(k, _)| *k == name) {
                        Some((_, n)) => *n += 1,
                        None => local.push((name, 1)),
                    }
                }
                tallies.lock().unwrap().extend(local);
            });
        }
        let engine = &engine;
        let workers_live = &workers_live;
        s.spawn(move || {
            let cp = ControlPlane::new(engine);
            let mut k = 0u64;
            while workers_live.load(std::sync::atomic::Ordering::Relaxed) > 0 {
                let target = if k % 2 == 0 { "solo" } else { "duo" };
                cp.promote("bank1", target).unwrap();
                k += 1;
            }
            assert!(k > 0);
        });
    });
    engine.drain_shadows();

    // Sequentially merged oracle.
    let mut oracle: Vec<(String, u64)> = Vec::new();
    for (name, n) in tallies.into_inner().unwrap() {
        match oracle.iter_mut().find(|(k, _)| *k == name) {
            Some((_, total)) => *total += n,
            None => oracle.push((name, n)),
        }
    }
    let total: u64 = oracle.iter().map(|(_, n)| n).sum();
    assert_eq!(total, (threads * per_thread) as u64);
    for (predictor, expect) in &oracle {
        assert_eq!(
            engine.lake.count_for("bank1", predictor) as u64,
            *expect,
            "count_for(bank1,{predictor}) diverged from the oracle"
        );
        assert_eq!(
            engine.lake.records_for("bank1", predictor).len() as u64,
            *expect,
            "scan of (bank1,{predictor}) diverged from the oracle"
        );
    }
    assert_eq!(engine.lake.len() as u64, total, "len() diverged from the oracle");
    assert_eq!(engine.hot.requests_live.get(), total);
    assert_eq!(engine.lake.forced_overwrites(), 0);
    assert_eq!(engine.lake.lost_appends(), 0);
}

#[test]
fn sharded_lake_eviction_stays_bounded_and_exact_under_concurrency() {
    // Small cap, concurrent writers pushing far past it: the bound
    // must hold exactly and the per-pair counts must equal a scan.
    let (_fix, engine) = sim_engine(512, 8);
    std::thread::scope(|s| {
        for w in 0..8usize {
            let engine = &engine;
            s.spawn(move || {
                let mut wl = Workload::new(TenantProfile::new("bank1", 80 + w as u64, 0.3, 0.1), 5);
                for i in 0..500 {
                    let e = wl.next_event();
                    engine
                        .score(&ScoreRequest {
                            intent: Intent {
                                tenant: "bank1".into(),
                                ..Intent::default()
                            },
                            entity: format!("ev{w}-{i}"),
                            features: e.features,
                        })
                        .unwrap();
                }
            });
        }
    });
    engine.drain_shadows();
    assert_eq!(engine.lake.len(), 512, "eviction must bound the lake at the cap");
    assert_eq!(
        engine.lake.count_for("bank1", "duo"),
        engine.lake.records_for("bank1", "duo").len(),
        "pair counts must stay exact under concurrent eviction"
    );
    assert_eq!(engine.lake.forced_overwrites(), 0);
    assert_eq!(engine.lake.lost_appends(), 0);
}
