//! Differential fuzz coverage for the streaming ingress plane
//! (`cargo test -q --test ingress_fuzz`).
//!
//! The incremental batch parser (`muse::server::streamjson`) promises
//! that chunking is *unobservable*: feeding a body byte-by-byte, in
//! random slices, or whole must produce the same events in the same
//! order, the same `"events"` shape, and — on rejection — the same
//! typed error (`JsonError`) with the same message at the same byte
//! offset as the buffered `util::json::parse`. These suites generate
//! thousands of valid and near-valid (byte-mutated) bodies and check
//! that promise against the buffered parser across chunk boundaries,
//! then once more end-to-end over HTTP against a `streamBatch: false`
//! twin server.
//!
//! A failure panics with the generated case's seed; replay with:
//!
//! ```text
//! MUSE_MB_SEED=<base_seed> cargo test --test ingress_fuzz <suite> -- --nocapture
//! ```
//!
//! (the per-case seed in the panic message pins the exact case), and
//! CI uploads `target/model-based-seeds/*.txt` on failure.

use muse::server::streamjson::{parse_chunked, BatchShape, StreamItem};
use muse::testkit::harness;
use muse::util::json::{parse, Json, JsonError};
use muse::util::prop::Gen;

// ---------------------------------------------------------------------
// Body generators (ASCII-only so byte mutations stay valid UTF-8)
// ---------------------------------------------------------------------

fn ws(g: &mut Gen) -> String {
    let n = g.usize(0..4);
    (0..n)
        .map(|_| *g.pick(&[' ', '\t', '\n', '\r']))
        .collect()
}

fn gen_string(g: &mut Gen) -> String {
    let n = g.usize(0..8);
    let s: String = (0..n)
        .map(|_| *g.pick(&['a', 'b', 'z', 'T', '0', '9', '_', '-', '.', ' ']))
        .collect();
    format!("\"{s}\"")
}

fn gen_number(g: &mut Gen) -> String {
    match g.usize(0..4) {
        0 => format!("{}", g.usize(0..1000)),
        1 => format!("-{}", g.usize(0..100)),
        2 => format!("{:.4}", g.f64(-10.0..10.0)),
        _ => format!("{:.2}e{}", g.f64(0.0..9.0), g.usize(0..3)),
    }
}

/// A random JSON value, depth-bounded.
fn gen_value(g: &mut Gen, depth: usize) -> String {
    let top = if depth == 0 { 5 } else { 7 };
    match g.usize(0..top) {
        0 => "null".to_string(),
        1 => "true".to_string(),
        2 => "false".to_string(),
        3 => gen_number(g),
        4 => gen_string(g),
        5 => {
            let n = g.usize(0..4);
            let items: Vec<String> = (0..n).map(|_| gen_value(g, depth - 1)).collect();
            format!("[{}{}]", ws(g), items.join(","))
        }
        _ => {
            let n = g.usize(0..4);
            let members: Vec<String> = (0..n)
                .map(|_| format!("{}{}: {}", ws(g), gen_string(g), gen_value(g, depth - 1)))
                .collect();
            format!("{{{}{}}}", members.join(","), ws(g))
        }
    }
}

/// One `"events"` element: usually a score-payload-shaped object,
/// sometimes an arbitrary value.
fn gen_event(g: &mut Gen) -> String {
    if g.bool(0.25) {
        return gen_value(g, 2);
    }
    let feats: Vec<String> = (0..g.usize(0..6)).map(|_| gen_number(g)).collect();
    let mut members = vec![
        format!("\"tenant\": {}", gen_string(g)),
        format!("\"features\": [{}]", feats.join(",")),
    ];
    if g.bool(0.3) {
        members.push(format!("\"entity\": {}", gen_string(g)));
    }
    if g.bool(0.2) {
        members.push(format!("{}: {}", gen_string(g), gen_value(g, 1)));
    }
    format!("{{{}{}}}", ws(g), members.join(","))
}

/// A batch body: usually a top-level object with an `"events"` member
/// somewhere among decoys; sometimes shapeless (missing/duplicate
/// `"events"`, non-array `"events"`, non-object top level).
fn gen_body(g: &mut Gen) -> String {
    if g.bool(0.08) {
        return format!("{}{}{}", ws(g), gen_value(g, 2), ws(g));
    }
    let mut members: Vec<String> = Vec::new();
    let decoys = g.usize(0..3);
    for _ in 0..decoys {
        members.push(format!("{}: {}", gen_string(g), gen_value(g, 2)));
    }
    let events_copies = match g.usize(0..10) {
        0 => 0,          // missing events
        1 | 2 => 2,      // duplicate key (last wins)
        _ => 1,
    };
    for _ in 0..events_copies {
        if g.bool(0.15) {
            members.push(format!("\"events\": {}", gen_value(g, 1)));
        } else {
            let evs: Vec<String> = (0..g.usize(0..5)).map(|_| gen_event(g)).collect();
            members.push(format!("\"events\": [{}{}]", ws(g), evs.join(",")));
        }
    }
    // Shuffle member order (seeded).
    for i in (1..members.len()).rev() {
        members.swap(i, g.usize(0..i + 1));
    }
    let inner: Vec<String> = members
        .iter()
        .map(|m| format!("{}{m}{}", ws(g), ws(g)))
        .collect();
    format!("{}{{{}}}{}", ws(g), inner.join(","), ws(g))
}

/// Corrupt a valid body with 1..=3 ASCII byte edits (replace, insert
/// or delete) — the near-valid corpus that exercises error paths.
fn mutate(g: &mut Gen, body: &str) -> String {
    const BYTES: &[u8] = b"{}[]:,\"\\e0x d.-";
    let mut bytes = body.as_bytes().to_vec();
    for _ in 0..g.usize(1..4) {
        if bytes.is_empty() {
            bytes.push(*g.pick(BYTES));
            continue;
        }
        let at = g.usize(0..bytes.len());
        match g.usize(0..3) {
            0 => bytes[at] = *g.pick(BYTES),
            1 => bytes.insert(at, *g.pick(BYTES)),
            _ => {
                bytes.remove(at);
            }
        }
    }
    String::from_utf8(bytes).expect("ASCII edits keep UTF-8 valid")
}

// ---------------------------------------------------------------------
// Differential core
// ---------------------------------------------------------------------

/// The buffered path's view of a body (shared reference semantics).
fn reference(body: &str) -> Result<(Vec<Json>, BatchShape), JsonError> {
    let v = parse(body)?;
    let events = v.get("events");
    let shape = BatchShape {
        events_seen: events.is_some(),
        events_is_array: events.map(|e| e.as_arr().is_some()).unwrap_or(false),
    };
    let evs = events
        .and_then(Json::as_arr)
        .map(|a| a.to_vec())
        .unwrap_or_default();
    Ok((evs, shape))
}

/// The streaming parser's view under a fixed chunking pattern.
fn streamed(body: &str, chunks: &[usize]) -> Result<(Vec<Json>, BatchShape), JsonError> {
    let mut events = Vec::new();
    let mut sink = |item: StreamItem| match item {
        StreamItem::Event(v) => events.push(v),
        StreamItem::EventsRestart => events.clear(),
    };
    let shape = parse_chunked(body.as_bytes(), chunks, &mut sink)?;
    Ok((events, shape))
}

/// Assert `streamed(body, chunks)` is indistinguishable from
/// `reference(body)` — same events, same shape, or the same
/// `JsonError` (message *and* byte offset).
fn assert_differential(body: &str, chunks: &[usize]) -> Result<(), String> {
    let want = reference(body);
    let got = streamed(body, chunks);
    match (&want, &got) {
        (Ok((wev, wsh)), Ok((gev, gsh))) => {
            if wev != gev {
                return Err(format!(
                    "event divergence under chunks {chunks:?} for {body:?}: \
                     buffered saw {} events, streamed {}",
                    wev.len(),
                    gev.len()
                ));
            }
            if wsh != gsh {
                return Err(format!(
                    "shape divergence under chunks {chunks:?} for {body:?}: \
                     buffered {wsh:?}, streamed {gsh:?}"
                ));
            }
        }
        (Err(we), Err(ge)) => {
            if we != ge {
                return Err(format!(
                    "error divergence under chunks {chunks:?} for {body:?}: \
                     buffered '{we}' (offset {}), streamed '{ge}' (offset {})",
                    we.offset, ge.offset
                ));
            }
        }
        _ => {
            return Err(format!(
                "accept/reject divergence under chunks {chunks:?} for {body:?}: \
                 buffered {:?}, streamed {:?}",
                want.as_ref().map(|_| "accepted").map_err(|e| e.to_string()),
                got.as_ref().map(|_| "accepted").map_err(|e| e.to_string()),
            ));
        }
    }
    Ok(())
}

/// Run the differential across the chunkings that matter: whole-body,
/// byte-by-byte, every two-chunk split (all byte boundaries), and a
/// few seeded irregular patterns.
fn assert_chunk_invariant(g: &mut Gen, body: &str) -> Result<(), String> {
    assert_differential(body, &[])?;
    assert_differential(body, &[1])?;
    for split in 1..body.len() {
        assert_differential(body, &[split, body.len() - split])?;
    }
    for _ in 0..4 {
        let pattern: Vec<usize> = (0..g.usize(1..5)).map(|_| g.usize(1..9)).collect();
        assert_differential(body, &pattern)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Suites
// ---------------------------------------------------------------------

/// Valid-ish generated bodies: every chunking agrees with the
/// buffered parser event-for-event (including duplicate-`"events"`
/// restarts and non-object top levels).
#[test]
fn fuzz_generated_bodies_parse_chunk_invariantly() {
    harness::check_logged(
        "fuzz_generated_bodies_parse_chunk_invariantly",
        harness::base_seed(0x4947_A001),
        60,
        |g| {
            let body = gen_body(g);
            assert_chunk_invariant(g, &body)
        },
    );
}

/// Byte-mutated (near-valid) bodies: rejections must carry the same
/// message at the same byte offset no matter where the chunk
/// boundaries fall.
#[test]
fn fuzz_mutated_bodies_reject_identically_at_every_boundary() {
    harness::check_logged(
        "fuzz_mutated_bodies_reject_identically_at_every_boundary",
        harness::base_seed(0x4947_A002),
        60,
        |g| {
            let body = mutate(g, &gen_body(g));
            assert_chunk_invariant(g, &body)
        },
    );
}

/// End-to-end twin-server differential: the same generated bodies go
/// through a streaming server and a `streamBatch: false` buffered
/// server; status line and body must match byte-for-byte.
#[test]
fn fuzz_http_streamed_vs_buffered_servers_agree_bytewise() {
    use muse::config::MuseConfig;
    use muse::coordinator::Engine;
    use muse::runtime::{ModelPool, SimArtifacts};
    use muse::server::http::http_request;
    use std::sync::Arc;

    const YAML: &str = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p"
predictors:
- name: p
  experts: [s3]
  quantile: identity
"#;
    let fix = SimArtifacts::in_temp().expect("sim fixture");
    let spawn = |extra: &str| {
        let pool = Arc::new(ModelPool::new(fix.manifest().unwrap()));
        let yaml = format!("{YAML}{extra}");
        let engine =
            Arc::new(Engine::build(&MuseConfig::from_yaml(&yaml).unwrap(), pool).unwrap());
        muse::server::spawn_server(engine, "127.0.0.1:0", 2, 0)
            .unwrap()
            .0
    };
    let streaming = spawn("");
    let buffered = spawn("server:\n  streamBatch: false\n");

    harness::check_logged(
        "fuzz_http_streamed_vs_buffered_servers_agree_bytewise",
        harness::base_seed(0x4947_A003),
        40,
        |g| {
            let body = if g.bool(0.5) {
                gen_body(g)
            } else {
                mutate(g, &gen_body(g))
            };
            let a = http_request(&streaming, "POST", "/v1/score/batch", &body)
                .map_err(|e| format!("streaming request failed: {e}"))?;
            let b = http_request(&buffered, "POST", "/v1/score/batch", &body)
                .map_err(|e| format!("buffered request failed: {e}"))?;
            if a != b {
                return Err(format!(
                    "HTTP divergence for body {body:?}: streaming {a:?}, buffered {b:?}"
                ));
            }
            Ok(())
        },
    );
}
