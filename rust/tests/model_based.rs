//! Model-based verification suite (`cargo test -q --test model_based`).
//!
//! Every test replays ≥ 50 generated scenarios through the production
//! engine *and* the sequential oracle (`muse::testkit`), diffing the
//! two. A failure panics with the generated case's seed; replay it
//! with the recipe in docs/TESTING.md:
//!
//! ```text
//! MUSE_MB_SEED=<base_seed> cargo test --test model_based <suite> -- --nocapture
//! ```
//!
//! (the per-case seed in the panic message pins the exact case via
//! `prop::check_seeded`), and CI uploads
//! `target/model-based-seeds/*.txt` on failure.
//!
//! Invariant catalog (docs/TESTING.md has the long form):
//!
//! 1. **Oracle score equality** — single-threaded, every response is
//!    bitwise-equal to the naive staged arithmetic, across generated
//!    topologies and control-plane storms; final lake/counters/tables
//!    agree exactly, in append order.
//! 2. **Lake/count oracle-exactness under concurrent swap storms** —
//!    4 scorer threads race through promote/deploy/decommission
//!    barriers; responses stay bitwise-deterministic, the sharded
//!    seqlock lake's merged reads equal the oracle's Mutex-VecDeque
//!    as multisets with exact per-pair counts and zero degradation.
//! 3. **Seamless-update alert-rate stability** — for generated drift
//!    storms with ≥ 2 promotions, the tenant's alert rate at its
//!    configured threshold returns to target after every promotion
//!    while the raw score distribution demonstrably shifts (and never
//!    does worse than keeping the old transformation).
//! 4. **Cluster-wide seamlessness** — the same generated storms
//!    replayed against an N-node `MuseCluster` (two-phase publish,
//!    rendezvous gateway, a crash armed mid-promotion, a join by log
//!    replay and a graceful leave): every response is bitwise-equal to
//!    the single oracle with an exact committed-epoch attribution
//!    window, and the cluster-aggregated lake/counters/tenant
//!    accounting is exactly conserved.

use muse::runtime::SimArtifacts;
use muse::testkit::{gen, harness};

/// Invariant 1: single-threaded bitwise oracle equality.
#[test]
fn model_oracle_single_thread_bitwise_equality() {
    let fix = SimArtifacts::in_temp().expect("sim fixture");
    harness::check_logged(
        "model_oracle_single_thread_bitwise_equality",
        harness::base_seed(0x4D42_5345),
        60,
        |g| {
            let trace = gen::trace(g, false);
            harness::run_trace_single(&fix, &trace)
        },
    );
}

/// Invariant 2: concurrent swap storms — multiset lake exactness,
/// O(1) count oracle-exactness, bitwise response determinism.
#[test]
fn model_oracle_concurrent_swap_storm_exactness() {
    let fix = SimArtifacts::in_temp().expect("sim fixture");
    harness::check_logged(
        "model_oracle_concurrent_swap_storm_exactness",
        harness::base_seed(0x4D42_5757),
        50,
        |g| {
            let trace = gen::trace(g, true);
            harness::run_trace_concurrent(&fix, &trace, 4)
        },
    );
}

/// Invariant 4: cluster-wide seamlessness. Generated control storms
/// replicated over 4–6 nodes via two-phase publish, with the failure
/// schedule injected mid-storm (crash mid-promotion, join by log
/// replay, graceful leave) and events scored through the rendezvous
/// gateway from 4 client threads.
#[test]
fn model_cluster_two_phase_publish_exactness() {
    let fix = SimArtifacts::in_temp().expect("sim fixture");
    harness::check_logged(
        "model_cluster_two_phase_publish_exactness",
        harness::base_seed(0x4D42_434C),
        12,
        |g| {
            let trace = gen::trace(g, false);
            let nodes = g.usize(4..7);
            harness::run_cluster_trace(&fix, &trace, nodes, 4)
        },
    );
}

/// Invariant 3: the seamless-update metamorphic check — alert-rate
/// stability across ≥ 2 refit+promotion cycles under generated drift.
#[test]
fn model_seamless_update_alert_rate_stability() {
    let fix = SimArtifacts::in_temp().expect("sim fixture");
    harness::check_logged(
        "model_seamless_update_alert_rate_stability",
        harness::base_seed(0x4D42_5550),
        50,
        |g| {
            let storm = gen::update_storm(g);
            let report = harness::run_update_storm(&fix, &storm)?;
            if report.promotions < 2 {
                return Err(format!(
                    "storm completed only {} promotions (need >= 2)",
                    report.promotions
                ));
            }
            if report.rates.len() != 3 {
                return Err(format!("expected 3 rate windows, got {:?}", report.rates));
            }
            Ok(())
        },
    );
}
