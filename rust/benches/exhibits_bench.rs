//! `cargo bench` target: regenerate every paper exhibit end-to-end and
//! time it. This is the "one bench per table/figure" entry point — the
//! printed rows/series are the same ones `muse repro all` emits.

use std::time::Instant;

fn main() {
    let exhibits: Vec<(&str, fn() -> anyhow::Result<String>)> = vec![
        ("Figure 4 (quantile transformation update)", muse::repro::fig4::run),
        ("Figure 5 (rolling update + warm-up)", muse::repro::fig5::run),
        ("Figure 6 (live model update)", muse::repro::fig6::run),
        ("Table 1 (posterior correction calibration)", muse::repro::table1::run),
        ("Appendix A (Eq. 5 sample-size bound)", muse::repro::appendix_a::run),
        ("Headline (throughput/latency SLOs)", muse::repro::headline::run),
        ("Section 2.2.1 (infrastructure dedup)", muse::repro::dedup::run),
        ("Section 4 (baseline comparison)", muse::repro::baselines_cmp::run),
    ];
    let needs_artifacts = ["Figure 4", "Figure 6", "Table 1", "Headline"];
    let have_artifacts = muse::runtime::Manifest::load(muse::runtime::Manifest::default_root()).is_ok();
    for (name, f) in exhibits {
        if !have_artifacts && needs_artifacts.iter().any(|p| name.starts_with(p)) {
            println!("-- {name}: skipped (artifacts not built)");
            continue;
        }
        let t0 = Instant::now();
        match f() {
            Ok(out) => {
                println!("{out}");
                println!("-- {name}: regenerated in {:.2}s\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => println!("-- {name}: ERROR {e:#}\n"),
        }
    }
}
