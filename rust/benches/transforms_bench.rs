//! `cargo bench` target: the transformation hot path — Posterior
//! Correction (Eq. 3), aggregation, Quantile Mapping lookups (Eq. 4)
//! at paper-scale grid sizes, the full per-event pipeline, and the
//! offline fitting costs (empirical quantile fit + Beta-mixture DE).
//!
//! Backs the paper's "negligible latency overhead" claim for T^C/A and
//! the O(log N) lookup cost of T^Q.

use muse::coldstart::{fit_mixture, FitConfig};
use muse::transforms::{
    quantile_fit, Aggregation, PosteriorCorrection, QuantileMap, ReferenceDistribution,
};
use muse::util::bench::{bench, section};
use muse::util::rng::Rng;

fn main() {
    section("posterior correction (Eq. 3)");
    let pc = PosteriorCorrection::new(0.18).unwrap();
    let mut i = 0u64;
    println!(
        "{}",
        bench("T^C scalar apply", 1_000, 5_000_000, || {
            i = i.wrapping_add(1);
            std::hint::black_box(pc.apply((i % 1000) as f64 / 1000.0));
        })
        .report()
    );

    section("aggregation A (weighted mean, K=8)");
    let agg = Aggregation::weighted(vec![1.0; 8]).unwrap();
    let scores = [0.1, 0.2, 0.05, 0.4, 0.3, 0.02, 0.15, 0.25];
    println!(
        "{}",
        bench("A apply_unchecked K=8", 1_000, 5_000_000, || {
            std::hint::black_box(agg.apply_unchecked(&scores));
        })
        .report()
    );

    section("quantile mapping T^Q (Eq. 4), binary search + lerp");
    for n_points in [65usize, 257, 1025, 4097] {
        let src: Vec<f64> = (0..n_points)
            .map(|i| (i as f64 / (n_points - 1) as f64).powi(2))
            .collect();
        let mut src = src;
        quantile_fit::dedup_monotone(&mut src);
        let refq: Vec<f64> = (0..n_points)
            .map(|i| i as f64 / (n_points - 1) as f64)
            .collect();
        let q = QuantileMap::new(src, refq).unwrap();
        let mut k = 0u64;
        println!(
            "{}",
            bench(&format!("T^Q apply N={}", n_points - 1), 1_000, 2_000_000, || {
                k = k.wrapping_add(1);
                std::hint::black_box(q.apply((k % 1000) as f64 / 1000.0));
            })
            .report()
        );
    }

    section("full per-event pipeline: 8x T^C -> A -> T^Q(N=1024)");
    let reference = ReferenceDistribution::fraud_default();
    let refq = reference.quantile_grid(1025);
    let mut rng = Rng::new(1);
    let sample: Vec<f64> = (0..100_000).map(|_| rng.beta(1.3, 14.0)).collect();
    let q = quantile_fit::fit_from_scores(&sample, &refq).unwrap();
    let mut k = 0u64;
    println!(
        "{}",
        bench("pipeline per event", 1_000, 2_000_000, || {
            k = k.wrapping_add(1);
            let s = (k % 1000) as f64 / 1000.0;
            let mut cal = [0.0f64; 8];
            for (j, c) in cal.iter_mut().enumerate() {
                *c = pc.apply(s * (1.0 + j as f64 * 0.01));
            }
            std::hint::black_box(q.apply(agg.apply_unchecked(&cal)));
        })
        .report()
    );

    section("offline fitting");
    println!(
        "{}",
        bench("empirical quantile fit (100k scores, N=1024)", 1, 8, || {
            std::hint::black_box(quantile_fit::fit_from_scores(&sample, &refq).unwrap());
        })
        .report()
    );
    let small: Vec<f64> = sample.iter().take(20_000).cloned().collect();
    let cfg = FitConfig {
        n_trials: 2,
        generations: 60,
        ..FitConfig::default()
    };
    println!(
        "{}",
        bench("Beta-mixture DE fit (20k scores, 2 trials)", 0, 3, || {
            std::hint::black_box(fit_mixture(&small, 0.015, &cfg).unwrap());
        })
        .report()
    );
}
