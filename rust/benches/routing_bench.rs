//! `cargo bench` target: intent-routing overhead (Section 2.5.1's
//! "negligible overhead" claim) — rule matching at realistic and
//! adversarial rule-table sizes, plus config hot-swap cost.

use muse::config::{Condition, Intent, RoutingConfig, ScoringRule, ShadowRule};
use muse::coordinator::Router;
use muse::util::bench::{bench, section};

fn rules(n: usize) -> RoutingConfig {
    let mut scoring: Vec<ScoringRule> = (0..n)
        .map(|i| ScoringRule {
            description: format!("tenant {i}"),
            condition: Condition {
                tenants: vec![format!("tenant-{i}")],
                ..Condition::default()
            },
            target_predictor: format!("p{}", i % 7),
        })
        .collect();
    scoring.push(ScoringRule {
        description: "catch-all".into(),
        condition: Condition::default(),
        target_predictor: "global".into(),
    });
    RoutingConfig {
        scoring_rules: scoring,
        shadow_rules: vec![ShadowRule {
            description: "shadow".into(),
            condition: Condition {
                tenants: vec!["tenant-0".into()],
                ..Condition::default()
            },
            target_predictors: vec!["shadow-p".into()],
        }],
    }
}

fn main() {
    section("intent routing: sequential scoring rules + parallel shadows");
    for n in [4usize, 32, 128, 512] {
        let router = Router::new(rules(n));
        // Best case: first rule hits.
        let first = Intent {
            tenant: "tenant-0".into(),
            ..Intent::default()
        };
        // Worst case: falls through every rule to the catch-all.
        let miss = Intent {
            tenant: "nobody".into(),
            ..Intent::default()
        };
        println!(
            "{}",
            bench(&format!("resolve first-match ({n} rules)"), 1_000, 1_000_000, || {
                std::hint::black_box(router.resolve(&first).unwrap());
            })
            .report()
        );
        println!(
            "{}",
            bench(&format!("resolve catch-all    ({n} rules)"), 1_000, 1_000_000, || {
                std::hint::black_box(router.resolve(&miss).unwrap());
            })
            .report()
        );
    }

    section("routing config hot swap (rolling update step)");
    let router = Router::new(rules(128));
    println!(
        "{}",
        bench("snapshot + swap 128-rule config", 100, 200_000, || {
            let cfg = router.snapshot().as_ref().clone();
            router.swap(cfg);
        })
        .report()
    );
}
