//! `cargo bench` target: intent-routing overhead (Section 2.5.1's
//! "negligible overhead" claim) — rule matching at realistic and
//! adversarial rule-table sizes, config hot-swap cost, and
//! multi-threaded contention: the lock-free `SnapCell` router vs a
//! seed-replica `RwLock` router, 1/4/8 threads, quiescent vs under a
//! continuous swap storm. Numbers are recorded in EXPERIMENTS.md
//! ("Contention").

use muse::config::{Condition, Intent, RoutingConfig, ScoringRule, ShadowRule};
use muse::coordinator::{Resolution, Router};
use muse::simulator::{swap_storm, SwapStormConfig};
use muse::util::bench::{bench, section, CountdownGuard};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

fn rules(n: usize) -> RoutingConfig {
    let mut scoring: Vec<ScoringRule> = (0..n)
        .map(|i| ScoringRule {
            description: format!("tenant {i}"),
            condition: Condition {
                tenants: vec![format!("tenant-{i}")],
                ..Condition::default()
            },
            target_predictor: format!("p{}", i % 7).into(),
        })
        .collect();
    scoring.push(ScoringRule {
        description: "catch-all".into(),
        condition: Condition::default(),
        target_predictor: "global".into(),
    });
    RoutingConfig {
        scoring_rules: scoring,
        shadow_rules: vec![ShadowRule {
            description: "shadow".into(),
            condition: Condition {
                tenants: vec!["tenant-0".into()],
                ..Condition::default()
            },
            target_predictors: vec!["shadow-p".into()],
        }],
    }
}

/// The seed's router, preserved as the contention baseline: an
/// `RwLock<Arc<RoutingConfig>>` snapshot plus per-request `String`
/// clones of every target name.
struct RwLockRouter {
    config: RwLock<Arc<RoutingConfig>>,
}

impl RwLockRouter {
    fn new(config: RoutingConfig) -> Self {
        RwLockRouter {
            config: RwLock::new(Arc::new(config)),
        }
    }

    fn swap(&self, config: RoutingConfig) {
        *self.config.write().unwrap() = Arc::new(config);
    }

    fn resolve(&self, intent: &Intent) -> Option<(String, Vec<String>, usize)> {
        let cfg = Arc::clone(&self.config.read().unwrap());
        let mut live = None;
        for (i, rule) in cfg.scoring_rules.iter().enumerate() {
            if rule.condition.matches(intent) {
                live = Some((rule.target_predictor.to_string(), i));
                break;
            }
        }
        let (live, rule_index) = live?;
        let mut shadows: Vec<String> = Vec::new();
        for rule in &cfg.shadow_rules {
            if rule.condition.matches(intent) {
                for t in &rule.target_predictors {
                    if &**t != live.as_str() && !shadows.iter().any(|s| s.as_str() == &**t) {
                        shadows.push(t.to_string());
                    }
                }
            }
        }
        Some((live, shadows, rule_index))
    }
}

/// Multi-threaded resolve throughput: `threads` workers resolving for
/// ~`per_thread` iterations each, optionally under a swap storm.
/// Returns (total events/s, swaps performed).
fn contention_run(
    threads: usize,
    per_thread: usize,
    storm: bool,
    resolve: impl Fn(&Intent) -> usize + Sync,
    swap: impl Fn() + Sync,
) -> (f64, u64) {
    let live_workers = AtomicU64::new(threads as u64);
    let swaps = AtomicU64::new(0);
    let total = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let resolve = &resolve;
            let live_workers = &live_workers;
            let total = &total;
            s.spawn(move || {
                // Panic-safe: releases the storm loop on unwind.
                let _live = CountdownGuard(live_workers);
                let first = Intent {
                    tenant: "tenant-0".into(),
                    ..Intent::default()
                };
                let miss = Intent {
                    tenant: "nobody".into(),
                    ..Intent::default()
                };
                let mut acc = 0usize;
                for i in 0..per_thread {
                    let intent = if (i + t) % 2 == 0 { &first } else { &miss };
                    acc = acc.wrapping_add(resolve(intent));
                }
                std::hint::black_box(acc);
                total.fetch_add(per_thread as u64, Ordering::Relaxed);
            });
        }
        if storm {
            let swap = &swap;
            let live_workers = &live_workers;
            let swaps = &swaps;
            s.spawn(move || {
                while live_workers.load(Ordering::Relaxed) > 0 {
                    swap();
                    swaps.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    (
        total.load(Ordering::Relaxed) as f64 / wall.max(1e-9),
        swaps.load(Ordering::Relaxed),
    )
}

fn main() {
    section("intent routing: sequential scoring rules + parallel shadows");
    for n in [4usize, 32, 128, 512] {
        let router = Router::new(rules(n));
        // Best case: first rule hits.
        let first = Intent {
            tenant: "tenant-0".into(),
            ..Intent::default()
        };
        // Worst case: falls through every rule to the catch-all.
        let miss = Intent {
            tenant: "nobody".into(),
            ..Intent::default()
        };
        println!(
            "{}",
            bench(&format!("resolve first-match ({n} rules)"), 1_000, 1_000_000, || {
                std::hint::black_box(router.resolve(&first).unwrap());
            })
            .report()
        );
        println!(
            "{}",
            bench(&format!("resolve catch-all    ({n} rules)"), 1_000, 1_000_000, || {
                std::hint::black_box(router.resolve(&miss).unwrap());
            })
            .report()
        );
    }

    section("routing config hot swap (rolling update step)");
    let router = Router::new(rules(128));
    println!(
        "{}",
        bench("snapshot + swap 128-rule config", 100, 200_000, || {
            let cfg = router.snapshot().as_ref().clone();
            router.swap(cfg);
        })
        .report()
    );

    section("contention: SnapCell router vs seed RwLock router (128 rules)");
    let per_thread = 400_000usize;
    for &threads in &[1usize, 4, 8] {
        for &storm in &[false, true] {
            let label = if storm { "swap storm" } else { "quiescent " };

            let snap_router = Router::new(rules(128));
            let (eps, swaps) = contention_run(
                threads,
                per_thread,
                storm,
                |intent| {
                    let r: Resolution = snap_router.resolve(intent).unwrap();
                    r.rule_index
                },
                || snap_router.swap(rules(128)),
            );
            println!(
                "  snapcell {threads}T {label}: {eps:>12.0} resolves/s   ({swaps} swaps)"
            );

            let lock_router = RwLockRouter::new(rules(128));
            let (eps, swaps) = contention_run(
                threads,
                per_thread,
                storm,
                |intent| lock_router.resolve(intent).unwrap().2,
                || lock_router.swap(rules(128)),
            );
            println!(
                "  rwlock   {threads}T {label}: {eps:>12.0} resolves/s   ({swaps} swaps)"
            );
        }
    }

    section("swap-under-load scenario (simulator::swap_storm)");
    let report = swap_storm(&SwapStormConfig {
        workers: 8,
        requests_per_worker: 200_000,
        min_swaps: 2_000,
        rules: 32,
    });
    println!(
        "  8 workers under storm: {:.0} resolves/s, {} swaps, {} errors, {} torn, max resolve {:.1}us",
        report.throughput_per_s(),
        report.swaps,
        report.errors,
        report.torn,
        report.max_resolve_ns as f64 / 1e3
    );
    assert!(report.seamless(1_000_000_000), "storm was not seamless");
}
