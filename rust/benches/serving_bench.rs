//! `cargo bench` target: the serving stack on real PJRT models —
//! per-batch inference cost across the AOT variants, single-event
//! end-to-end engine latency, engine throughput under concurrency
//! (quiescent and under a control-plane promotion storm), and the
//! infra-dedup registry ops. Skips (with a message) when artifacts
//! are missing. Numbers are recorded in EXPERIMENTS.md.

use muse::config::{Intent, MuseConfig};
use muse::coordinator::{ControlPlane, Engine, ScoreRequest};
use muse::runtime::{Manifest, ModelPool};
use muse::simulator::{TenantProfile, Workload};
use muse::util::bench::{bench, section, CountdownGuard};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "trio"
predictors:
- name: trio
  experts: [m1, m2, m3]
  quantile: identity
- name: solo
  experts: [m1]
  quantile: identity
"#;

fn main() {
    let Ok(manifest) = Manifest::load(Manifest::default_root()) else {
        println!("serving_bench: artifacts not built, skipping (run `make artifacts`)");
        return;
    };

    section("PJRT container inference by batch variant (model m1)");
    let pool = Arc::new(ModelPool::new(manifest));
    let h = pool.acquire("m1").unwrap();
    let d = h.feature_dim;
    for &b in &[1usize, 16, 64, 256] {
        let features = vec![0.1f32; b * d];
        let r = bench(&format!("m1 infer batch={b}"), 50, 2_000, || {
            std::hint::black_box(h.infer(&features, b).unwrap());
        });
        println!(
            "{}   ({:.2} us/event)",
            r.report(),
            r.mean_ns / 1e3 / b as f64
        );
    }
    pool.release("m1");

    section("engine: single-event end-to-end (router -> 3-expert ensemble -> T^Q)");
    let engine = Arc::new(Engine::build(&MuseConfig::from_yaml(CONFIG).unwrap(), pool).unwrap());
    muse::coordinator::warm_up(&engine, 300, 3).unwrap();
    let mut wl = Workload::new(TenantProfile::new("bank1", 9, 0.4, 0.1), 4);
    let mut events: Vec<Vec<f32>> = (0..4096).map(|_| wl.next_event().features).collect();
    let mut k = 0usize;
    println!(
        "{}",
        bench("engine.score (live path)", 100, 20_000, || {
            let req = ScoreRequest {
                intent: Intent {
                    tenant: "bank1".into(),
                    ..Intent::default()
                },
                entity: String::new(),
                features: std::mem::take(&mut events[k % 4096]),
            };
            let resp = engine.score(&req).unwrap();
            events[k % 4096] = req.features;
            std::hint::black_box(resp.score);
            k += 1;
        })
        .report()
    );

    section("engine throughput under concurrency (8 client threads)");
    let done = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..8 {
            let engine = Arc::clone(&engine);
            let done = Arc::clone(&done);
            scope.spawn(move || {
                let mut wl =
                    Workload::new(TenantProfile::new("bank1", 20 + c as u64, 0.4, 0.1), 5);
                for i in 0..4_000 {
                    let e = wl.next_event();
                    let req = ScoreRequest {
                        intent: Intent {
                            tenant: "bank1".into(),
                            ..Intent::default()
                        },
                        entity: format!("{c}-{i}"),
                        features: e.features,
                    };
                    engine.score(&req).unwrap();
                    done.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "  {} events in {:.2}s = {:.0} events/s (paper cluster avg: 4500 eps)\n  {}",
        done.load(Ordering::Relaxed),
        wall,
        done.load(Ordering::Relaxed) as f64 / wall,
        engine.live_latency.summary()
    );

    section("engine throughput under a promotion storm (8 clients, seamless-update check)");
    // Deploy a second live candidate and ping-pong bank1 between the
    // two predictors as fast as the control plane can publish
    // snapshots, while 8 client threads keep scoring. The contract:
    // zero failed requests, throughput within noise of the quiescent
    // run above (EXPERIMENTS.md "Contention").
    {
        let cp = ControlPlane::new(&engine);
        let done = Arc::new(AtomicU64::new(0));
        let live_clients = AtomicU64::new(8);
        let swaps = AtomicU64::new(0);
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..8 {
                let engine = Arc::clone(&engine);
                let done = Arc::clone(&done);
                let live_clients = &live_clients;
                scope.spawn(move || {
                    // Panic-safe: a dropped request must stop the
                    // promotion loop, not hang the scope join.
                    let _live = CountdownGuard(live_clients);
                    let mut wl =
                        Workload::new(TenantProfile::new("bank1", 40 + c as u64, 0.4, 0.1), 6);
                    for i in 0..4_000 {
                        let e = wl.next_event();
                        let req = ScoreRequest {
                            intent: Intent {
                                tenant: "bank1".into(),
                                ..Intent::default()
                            },
                            entity: format!("s{c}-{i}"),
                            features: e.features,
                        };
                        engine.score(&req).expect("request dropped during promotion");
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
            let cp = &cp;
            let live_clients = &live_clients;
            let swaps = &swaps;
            scope.spawn(move || {
                let mut k = 0u64;
                while live_clients.load(Ordering::Relaxed) > 0 {
                    let target = if k % 2 == 0 { "solo" } else { "trio" };
                    cp.promote("bank1", target).unwrap();
                    k += 1;
                }
                swaps.store(k, Ordering::Relaxed);
            });
        });
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  {} events in {:.2}s = {:.0} events/s under {} promotions ({:.0} swaps/s), zero drops",
            done.load(Ordering::Relaxed),
            wall,
            done.load(Ordering::Relaxed) as f64 / wall,
            swaps.load(Ordering::Relaxed),
            swaps.load(Ordering::Relaxed) as f64 / wall
        );
        engine.drain_shadows();
    }

    section("registry ops (dedup bookkeeping)");
    let pool2 = engine.registry.pool();
    // Hold one reference so the bench measures refcounting, not
    // container spawn/compile.
    let _anchor = pool2.acquire("m2").unwrap();
    println!(
        "{}",
        bench("pool acquire+release (warm container)", 10, 50_000, || {
            let h = pool2.acquire("m2").unwrap();
            std::hint::black_box(&h);
            pool2.release("m2");
        })
        .report()
    );
    pool2.release("m2");
}
