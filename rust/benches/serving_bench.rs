//! `cargo bench` target: the serving stack — the fused-vs-staged
//! transform-pipeline comparison (synthetic expert scores, runs with
//! no artifacts), then on real PJRT models: per-batch inference cost
//! across the AOT variants, single-event end-to-end engine latency,
//! engine throughput under concurrency (quiescent and under a
//! control-plane promotion storm), end-to-end batch scoring through
//! `Engine::score_batch`, and the infra-dedup registry ops. The
//! tenant-state-plane section measures the 100k-tenant scale-out:
//! string-map vs handle-slab probes, onboarding-storm republish cost,
//! and the `/metrics` scrape with 100k live tenant counters.
//! PJRT sections skip (with a message) when artifacts are missing.
//! Numbers are recorded in EXPERIMENTS.md.

use muse::config::{Intent, MuseConfig};
use muse::coordinator::{ControlPlane, Engine, ScoreRequest, TenantInterner};
use muse::datalake::DataLake;
use muse::lifecycle::{QuantileSketch, ScoreFeed};
use muse::metrics::Counters;
use muse::runtime::{Manifest, ModelPool, SimArtifacts};
use muse::simulator::{run_batch_mix, BatchMixConfig, TenantProfile, Workload};
use muse::transforms::{
    Aggregation, PipelineScratch, PipelineSpec, PosteriorCorrection, QuantileMap,
};
use muse::util::bench::{bench, section, CountdownGuard};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "trio"
predictors:
- name: trio
  experts: [m1, m2, m3]
  quantile: identity
- name: solo
  experts: [m1]
  quantile: identity
"#;

/// Fused-vs-staged: the compiled pipeline kernel against a faithful
/// re-enactment of the seed's interpreted path (per-event `Option`
/// match, per-event aggregation, per-event tenant `HashMap` probe).
/// Pure transforms — no PJRT, so this section always runs.
fn bench_fused_vs_staged() {
    section("transform pipeline: compiled (fused) vs staged (seed-style interpretation)");
    let n = 4096usize;
    let n_points = 1025;
    let src: Vec<f64> = (0..n_points)
        .map(|i| (i as f64 / (n_points - 1) as f64).powi(2))
        .collect();
    let refq: Vec<f64> = (0..n_points)
        .map(|i| i as f64 / (n_points - 1) as f64)
        .collect();
    let map = QuantileMap::new(src, refq).unwrap().shared();
    // Per-event tenant probe, as the seed batcher did it.
    let mut tenant_maps: HashMap<String, Arc<QuantileMap>> = HashMap::new();
    for t in ["bank1", "bank2", "bank3", "bank4"] {
        tenant_maps.insert(t.to_string(), Arc::clone(&map));
    }
    let mut rng = muse::util::rng::Rng::new(77);

    for &k in &[3usize, 1] {
        let corrections: Vec<Option<PosteriorCorrection>> = (0..k)
            .map(|j| {
                if j == k - 1 {
                    None // mixed Some/None: the branch the kernel kills
                } else {
                    Some(PosteriorCorrection::new(0.1 + 0.2 * j as f64).unwrap())
                }
            })
            .collect();
        let aggregation = if k == 1 {
            Aggregation::Identity
        } else {
            Aggregation::weighted(vec![1.0, 1.0, 2.0]).unwrap()
        };
        let spec =
            PipelineSpec::new(corrections.clone(), aggregation.clone(), Arc::clone(&map))
                .unwrap();
        let compiled = spec.compile().unwrap();

        // SoA lanes for the compiled kernel; same values event-major
        // for the staged loop.
        let mut scratch = PipelineScratch::default();
        scratch.begin(k, n);
        let mut event_major = vec![0.0f32; n * k];
        for j in 0..k {
            let lane = scratch.lane_mut(j);
            for i in 0..n {
                let s = rng.f64() as f32;
                lane[i] = s;
                event_major[i * k + j] = s;
            }
        }

        let label = if compiled.is_fused() {
            format!("k={k} (fused to single PWL lookup)")
        } else {
            format!("k={k} (branch-free slots + dot + PWL)")
        };

        let mut calibrated = vec![0.0f64; k];
        let mut sink = 0.0f64;
        let r_staged = bench(&format!("staged  {label}"), 5, 200, || {
            for i in 0..n {
                for (j, c) in corrections.iter().enumerate() {
                    let s = event_major[i * k + j] as f64;
                    calibrated[j] = match c {
                        Some(c) => c.apply(s),
                        None => s,
                    };
                }
                let raw = aggregation.apply_unchecked(&calibrated);
                // Seed semantics: one tenant map probe per event.
                let q = tenant_maps.get("bank1").unwrap();
                sink += q.apply(raw);
            }
        });
        let mut raw_buf: Vec<f64> = Vec::new();
        let mut out_buf: Vec<f64> = Vec::new();
        let r_compiled = bench(&format!("compiled {label}"), 5, 200, || {
            raw_buf.clear();
            out_buf.clear();
            compiled.score_into(&scratch, &mut raw_buf, &mut out_buf);
            sink += out_buf[n - 1];
        });
        std::hint::black_box(sink);
        println!(
            "{}   ({:.1} ns/event)",
            r_staged.report(),
            r_staged.mean_ns / n as f64
        );
        let ratio = r_staged.mean_ns / r_compiled.mean_ns;
        println!(
            "{}   ({:.1} ns/event, {:.2}x vs staged)",
            r_compiled.report(),
            r_compiled.mean_ns / n as f64,
            ratio
        );
        if ratio < 1.0 {
            // The acceptance criterion is "compiled no slower than
            // staged"; a bench can't hard-fail on a noisy shared VM,
            // so make the violation impossible to miss in the output.
            println!(
                "  *** WARNING: compiled kernel SLOWER than staged ({ratio:.2}x) — \
                 acceptance bar violated, investigate before updating EXPERIMENTS.md ***"
            );
        }
    }
}

/// Scoring kernels: each lane-parallel kernel against the scalar
/// path it must stay bitwise-equal to, plus the tenant-probe cost the
/// handle interning removed. Pure transforms — always runs. The
/// equivalence itself is pinned by property tests
/// (`transforms::quantile::tests`, `transforms::pipeline::tests`);
/// this section records only the speed side of the contract.
fn bench_scoring_kernels() {
    section("scoring kernels: lane-parallel (8-wide) vs scalar");
    let n = 4096usize;
    let mut rng = muse::util::rng::Rng::new(41);
    let base: Vec<f64> = (0..n).map(|_| rng.f64() * 1.4 - 0.2).collect();

    // PWL quantile lookup, both grid regimes: small grids take the
    // counting scan, large grids the lane-interleaved CMOV search.
    for &(n_points, regime) in &[(33usize, "counting scan"), (1025usize, "CMOV search")] {
        let src: Vec<f64> = (0..n_points)
            .map(|i| (i as f64 / (n_points - 1) as f64).powi(2))
            .collect();
        let refq: Vec<f64> = (0..n_points)
            .map(|i| i as f64 / (n_points - 1) as f64)
            .collect();
        let map = QuantileMap::new(src, refq).unwrap();
        let mut sink = 0.0f64;
        let r_scalar = bench(
            &format!("T^Q scalar apply      ({n_points} knots)"),
            5,
            500,
            || {
                for &s in &base {
                    sink += map.apply(s);
                }
            },
        );
        println!("{}   ({:.1} ns/event)", r_scalar.report(), r_scalar.mean_ns / n as f64);
        let mut buf = vec![0.0f64; n];
        let r_lanes = bench(
            &format!("T^Q apply_batch 8-wide ({n_points} knots, {regime})"),
            5,
            500,
            || {
                buf.copy_from_slice(&base);
                map.apply_batch(&mut buf);
                sink += buf[n - 1];
            },
        );
        std::hint::black_box(sink);
        println!(
            "{}   ({:.1} ns/event, {:.2}x vs scalar)",
            r_lanes.report(),
            r_lanes.mean_ns / n as f64,
            r_scalar.mean_ns / r_lanes.mean_ns
        );
    }

    // Stage 1+2 (T^C + A): per-event raw_one vs the lane-parallel
    // raw_into kernel, k=3 with a mixed Some/None correction row.
    let k = 3usize;
    let corrections: Vec<Option<PosteriorCorrection>> = (0..k)
        .map(|j| {
            if j == k - 1 {
                None
            } else {
                Some(PosteriorCorrection::new(0.1 + 0.2 * j as f64).unwrap())
            }
        })
        .collect();
    let map = QuantileMap::identity(33).unwrap().shared();
    let spec = PipelineSpec::new(
        corrections,
        Aggregation::weighted(vec![1.0, 1.0, 2.0]).unwrap(),
        map,
    )
    .unwrap();
    let stages = Arc::clone(spec.compile().unwrap().stages());
    let mut scratch = PipelineScratch::default();
    scratch.begin(k, n);
    let mut event_major = vec![0.0f32; n * k];
    for j in 0..k {
        let lane = scratch.lane_mut(j);
        for i in 0..n {
            let s = rng.f64() as f32;
            lane[i] = s;
            event_major[i * k + j] = s;
        }
    }
    let mut sink = 0.0f64;
    let r_scalar = bench("T^C+A raw_one per event (k=3)", 5, 500, || {
        for i in 0..n {
            sink += stages.raw_one(&event_major[i * k..(i + 1) * k]);
        }
    });
    println!("{}   ({:.1} ns/event)", r_scalar.report(), r_scalar.mean_ns / n as f64);
    let mut raw = Vec::with_capacity(n);
    let r_lanes = bench("T^C+A raw_into 8-wide   (k=3)", 5, 500, || {
        raw.clear();
        stages.raw_into(&scratch, &mut raw);
        sink += raw[n - 1];
    });
    std::hint::black_box(sink);
    println!(
        "{}   ({:.1} ns/event, {:.2}x vs scalar)",
        r_lanes.report(),
        r_lanes.mean_ns / n as f64,
        r_scalar.mean_ns / r_lanes.mean_ns
    );

    // Tenant probe: the seed hashed the tenant string per event
    // (HashMap probe in the batcher, the counters, the admission
    // gate); the interner resolves once at the ingress edge and
    // everything downstream is a dense-vector index.
    let interner = TenantInterner::new();
    let by_handle: Vec<u8> = (0..64)
        .map(|i| {
            let h = interner.resolve(&format!("tenant-{i:03}"));
            (h.index() % 7) as u8
        })
        .collect();
    let names: Vec<String> = (0..64).map(|i| format!("tenant-{i:03}")).collect();
    let mut acc = 0u64;
    let mut i = 0usize;
    let r_str = bench("tenant probe by string (hash per event)", 2_000, 500_000, || {
        let h = interner.lookup(&names[i % names.len()]).unwrap();
        acc += by_handle[h.index()] as u64;
        i += 1;
    });
    println!("{}   ({:.1} ns/probe)", r_str.report(), r_str.mean_ns);
    let handles: Vec<_> = names.iter().map(|n| interner.resolve(n)).collect();
    let mut j = 0usize;
    let r_handle = bench("tenant probe by handle (dense index)", 2_000, 500_000, || {
        acc += by_handle[handles[j % handles.len()].index()] as u64;
        j += 1;
    });
    std::hint::black_box(acc);
    println!(
        "{}   ({:.1} ns/probe, {:.2}x vs string)",
        r_handle.report(),
        r_handle.mean_ns,
        r_str.mean_ns / r_handle.mean_ns
    );
}

/// Lifecycle sketch-feed overhead. Two layers:
///
/// 1. the raw primitives (ring append, sketch insert) — pure, always
///    runs;
/// 2. `Engine::score` with the autopilot on vs off, over the
///    synthetic sim-dialect artifacts, so the end-to-end delta of the
///    hot-path feed (one wait-free table load + one atomic ring
///    append; **zero added lock acquisitions**) is measured in situ —
///    no `make artifacts` required.
fn bench_lifecycle_overhead() {
    section("lifecycle: sketch feed hot-path overhead (per-worker rings, lock-free)");
    let feed = ScoreFeed::new(8, 8192);
    let r = bench("feed.push (fetch_add + store)", 10_000, 2_000_000, || {
        feed.push(0.42);
    });
    println!("{}   ({:.1} ns/event)", r.report(), r.mean_ns);
    let mut sketch = QuantileSketch::new(2048);
    let mut x = 0.0f64;
    let r = bench("sketch.insert (drainer side, off-path)", 10_000, 2_000_000, || {
        x = (x + 0.61803398875).fract();
        sketch.insert(x);
    });
    println!(
        "{}   ({:.1} ns/event, {} retained items over {} levels)",
        r.report(),
        r.mean_ns,
        sketch.memory_items(),
        sketch.levels()
    );

    let fix = match SimArtifacts::in_temp() {
        Ok(f) => f,
        Err(e) => {
            println!("  (skipping engine on/off comparison: {e})");
            return;
        }
    };
    const SIM_BASE: &str = r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "trio"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "trio"
predictors:
- name: trio
  experts: [s1, s2, s3]
  quantile: identity
server:
  workers: 2
  maxBatchDelayUs: 50
"#;
    const SIM_LC: &str = "
lifecycle:
  enabled: true
  tenants: [\"bank1\"]
  autoDiscover: false
";
    let mut results = Vec::new();
    for (label, yaml) in [
        ("engine.score, lifecycle off", SIM_BASE.to_string()),
        ("engine.score, lifecycle on ", format!("{SIM_BASE}{SIM_LC}")),
    ] {
        let pool = Arc::new(ModelPool::new(fix.manifest().unwrap()));
        let engine = Engine::build(&MuseConfig::from_yaml(&yaml).unwrap(), pool).unwrap();
        let mut wl = Workload::new(TenantProfile::new("bank1", 7, 0.3, 0.1), 11);
        let mut events: Vec<Vec<f32>> = (0..2048).map(|_| wl.next_event().features).collect();
        let mut k = 0usize;
        // Register the pair's feed so the hot path measures a *live*
        // record, not the cheaper unregistered miss.
        if let Some(hub) = &engine.lifecycle {
            let req = ScoreRequest {
                intent: Intent {
                    tenant: "bank1".into(),
                    ..Intent::default()
                },
                entity: String::new(),
                features: events[0].clone(),
            };
            engine.score(&req).unwrap();
            hub.tick(&engine).unwrap();
        }
        let r = bench(label, 200, 20_000, || {
            let req = ScoreRequest {
                intent: Intent {
                    tenant: "bank1".into(),
                    ..Intent::default()
                },
                entity: String::new(),
                features: std::mem::take(&mut events[k % 2048]),
            };
            let resp = engine.score(&req).unwrap();
            events[k % 2048] = req.features;
            std::hint::black_box(resp.score);
            k += 1;
        });
        println!("{}", r.report());
        results.push(r.mean_ns);
    }
    if let [off, on] = results[..] {
        println!(
            "  sketch-feed delta: {:+.1} ns/event ({:+.2}% — one wait-free table load + one \
             atomic ring append; no lock joins the hot path)",
            on - off,
            100.0 * (on - off) / off
        );
    }
}

/// A faithful re-enactment of the pre-refactor data lake — one global
/// `Mutex` around a `VecDeque` ring plus per-pair count maps, paying
/// two `String` allocations per append — used as the baseline the
/// sharded lock-free lake is measured against. Pure, always runs.
struct MutexLake {
    inner: Mutex<MutexLakeInner>,
    cap: usize,
}

#[derive(Default)]
struct MutexLakeInner {
    records: VecDeque<(String, String, f64, f64, bool, u64)>,
    counts: HashMap<String, HashMap<String, usize>>,
    seq: u64,
}

impl MutexLake {
    fn new(cap: usize) -> MutexLake {
        MutexLake {
            inner: Mutex::new(MutexLakeInner::default()),
            cap,
        }
    }

    fn append(&self, tenant: &str, predictor: &str, score: f64, raw: f64, shadow: bool) {
        let mut inner = self.inner.lock().unwrap();
        if self.cap > 0 && inner.records.len() >= self.cap {
            if let Some((t, p, ..)) = inner.records.pop_front() {
                if let Some(m) = inner.counts.get_mut(&t) {
                    if let Some(c) = m.get_mut(&p) {
                        *c = c.saturating_sub(1);
                    }
                }
            }
        }
        let seq = inner.seq;
        inner.seq += 1;
        *inner
            .counts
            .entry(tenant.to_string())
            .or_default()
            .entry(predictor.to_string())
            .or_insert(0) += 1;
        inner
            .records
            .push_back((tenant.to_string(), predictor.to_string(), score, raw, shadow, seq));
    }
}

/// Sharded-vs-global data lake: single-thread cost, then the
/// multi-threaded append race where the global mutex serializes and
/// the stripes do not. Pure, always runs.
fn bench_lake_sharded_vs_global() {
    section("observation plane: sharded lock-free lake vs global-mutex baseline");
    const CAP: usize = 1 << 16;
    let mutex_lake = MutexLake::new(CAP);
    let r = bench("mutex lake append (seed re-enactment)", 5_000, 500_000, || {
        mutex_lake.append("bank1", "p1", 0.5, 0.4, false);
    });
    println!("{}   ({:.1} ns/event)", r.report(), r.mean_ns);
    let lake = DataLake::with_shards(CAP, 8);
    let r_sharded = bench("sharded lake append (8 stripes)", 5_000, 500_000, || {
        lake.append("bank1", "p1", 0.5, 0.4, false);
    });
    println!(
        "{}   ({:.1} ns/event, {:.2}x vs mutex single-thread)",
        r_sharded.report(),
        r_sharded.mean_ns,
        r.mean_ns / r_sharded.mean_ns
    );

    for threads in [4usize, 8] {
        let per_thread = 200_000usize;
        let mutex_lake = MutexLake::new(CAP);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for w in 0..threads {
                let mutex_lake = &mutex_lake;
                s.spawn(move || {
                    let tenant = if w % 2 == 0 { "bank1" } else { "bank2" };
                    for _ in 0..per_thread {
                        mutex_lake.append(tenant, "p1", 0.5, 0.4, false);
                    }
                });
            }
        });
        let mutex_eps = (threads * per_thread) as f64 / t0.elapsed().as_secs_f64();

        let lake = DataLake::with_shards(CAP, 8);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for w in 0..threads {
                let lake = &lake;
                s.spawn(move || {
                    let tenant = if w % 2 == 0 { "bank1" } else { "bank2" };
                    for _ in 0..per_thread {
                        lake.append(tenant, "p1", 0.5, 0.4, false);
                    }
                });
            }
        });
        let sharded_eps = (threads * per_thread) as f64 / t0.elapsed().as_secs_f64();
        println!(
            "  {threads} threads: mutex {mutex_eps:>12.0} appends/s | sharded {sharded_eps:>12.0} appends/s ({:.2}x)",
            sharded_eps / mutex_eps
        );
        assert_eq!(lake.len(), CAP.min(threads * per_thread), "sharded lake lost records");
    }
}

/// Hot counters: the seed's fully-locked map, the new wait-free
/// name-keyed path, and the pre-resolved handle — single-thread cost
/// and the 8-thread contended case. Pure, always runs.
fn bench_hot_counters() {
    section("observation plane: wait-free counters vs locked-map baseline");
    // Seed re-enactment: every bump takes the registry mutex.
    let locked: Mutex<BTreeMap<String, AtomicU64>> = Mutex::new(BTreeMap::new());
    let r_locked = bench("locked map inc (seed re-enactment)", 10_000, 2_000_000, || {
        let mut map = locked.lock().unwrap();
        if let Some(c) = map.get("requests_live") {
            c.fetch_add(1, Ordering::Relaxed);
        } else {
            map.entry("requests_live".to_string())
                .or_insert_with(|| AtomicU64::new(0))
                .fetch_add(1, Ordering::Relaxed);
        }
    });
    println!("{}   ({:.1} ns/inc)", r_locked.report(), r_locked.mean_ns);

    let counters = Counters::new();
    let r_named = bench("wait-free named inc (snapshot+probe)", 10_000, 2_000_000, || {
        counters.inc("requests_live");
    });
    println!("{}   ({:.1} ns/inc)", r_named.report(), r_named.mean_ns);

    let handle = counters.handle("requests_live");
    let r_handle = bench("pre-resolved handle inc (one fetch_add)", 10_000, 2_000_000, || {
        handle.inc();
    });
    println!(
        "{}   ({:.1} ns/inc, {:.2}x vs locked map)",
        r_handle.report(),
        r_handle.mean_ns,
        r_locked.mean_ns / r_handle.mean_ns
    );

    let per_thread = 500_000usize;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..8 {
            let locked = &locked;
            s.spawn(move || {
                for _ in 0..per_thread {
                    let map = locked.lock().unwrap();
                    map["requests_live"].fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    let locked_ops = 8.0 * per_thread as f64 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..8 {
            let handle = handle.clone();
            s.spawn(move || {
                for _ in 0..per_thread {
                    handle.inc();
                }
            });
        }
    });
    let handle_ops = 8.0 * per_thread as f64 / t0.elapsed().as_secs_f64();
    println!(
        "  8 threads: locked {locked_ops:>12.0} incs/s | handle {handle_ops:>12.0} incs/s ({:.2}x)",
        handle_ops / locked_ops
    );
}

/// Tenant state plane at scale: the three costs the sharded-slab
/// registries change. (1) the per-event state probe — string-keyed
/// map vs dense-handle slab; (2) the onboarding storm — first-touch
/// republish cost of a single whole-map COW cell vs the sharded
/// interner; (3) the `/metrics` scrape at 100k tenant keys —
/// clone-then-serialize vs shard-streamed. Mostly pure; the scrape
/// half runs on the synthetic sim artifacts.
fn bench_tenant_state_plane() {
    use muse::util::slab::HandleSlab;
    use muse::util::swap::SnapCell;

    section("tenant state plane: sharded slab registries at 100k tenants");
    const N: usize = 100_000;
    let names: Vec<Arc<str>> = (0..N).map(|i| Arc::from(format!("tenant-{i:06}"))).collect();

    // (1) Feed-table probe: published string map vs handle slab, both
    // behind the same wait-free snapshot discipline the engine uses.
    // Payloads are warm-tier rings so the probe cost is measured on
    // the real value type.
    let ring = || Arc::new(muse::lifecycle::ScoreFeed::new(1, 8));
    let by_name: SnapCell<HashMap<Arc<str>, Arc<muse::lifecycle::ScoreFeed>>> = SnapCell::new(
        Arc::new(names.iter().map(|n| (Arc::clone(n), ring())).collect()),
    );
    let slab: HandleSlab<Arc<muse::lifecycle::ScoreFeed>> = HandleSlab::with_shards(16);
    for i in 0..N {
        slab.set(i, ring());
    }
    let mut acc = 0usize;
    let mut i = 0usize;
    let r_map = bench("feed probe by tenant string (hash per event)", 2_000, 500_000, || {
        let table = by_name.load();
        acc += table[&names[(i * 7919) % N]].memory_bytes();
        i += 1;
    });
    println!("{}   ({:.1} ns/probe)", r_map.report(), r_map.mean_ns);
    let mut j = 0usize;
    let r_slab = bench("feed probe by handle slab (dense index)  ", 2_000, 500_000, || {
        acc += slab.get((j * 7919) % N).unwrap().memory_bytes();
        j += 1;
    });
    std::hint::black_box(acc);
    println!(
        "{}   ({:.1} ns/probe, {:.2}x vs string map)",
        r_slab.report(),
        r_slab.mean_ns,
        r_map.mean_ns / r_slab.mean_ns
    );
    // Both probe paths are snapshot-load + indexed reads — no lock,
    // no CAS loop. Anchor the equivalence: every index the string map
    // serves, the slab serves too.
    let table = by_name.load();
    for k in (0..N).step_by(997) {
        assert!(
            slab.get(k).is_some() && table.contains_key(&names[k]),
            "probe surfaces disagree at index {k}"
        );
    }

    // (2) Onboarding storm: every first touch of the seed layout
    // cloned the whole name map under one writer lock — O(n^2) across
    // an n-tenant storm — so the re-enactment stops at 10k while the
    // sharded interner runs the full 100k.
    let cow: SnapCell<HashMap<Arc<str>, u32>> = SnapCell::new(Arc::new(HashMap::new()));
    let t0 = Instant::now();
    for (id, name) in names.iter().take(10_000).enumerate() {
        cow.rcu(|old| {
            let mut next = old.as_ref().clone();
            next.insert(Arc::clone(name), id as u32);
            (Arc::new(next), ())
        });
    }
    let cow_wall = t0.elapsed().as_secs_f64();
    println!(
        "  onboard 10k  whole-map COW (seed re-enactment): {:>8.3}s ({:.1} us/tenant)",
        cow_wall,
        cow_wall * 1e6 / 10_000.0
    );
    for count in [10_000usize, N] {
        let interner = TenantInterner::new();
        let t0 = Instant::now();
        for name in names.iter().take(count) {
            interner.resolve(name);
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  onboard {:>4}k sharded slab interner:            {:>8.3}s ({:.3} us/tenant{})",
            count / 1000,
            wall,
            wall * 1e6 / count as f64,
            if count == 10_000 {
                format!(", {:.0}x vs COW", cow_wall / wall)
            } else {
                String::new()
            }
        );
    }

    // (3) /metrics scrape with 100k live tenant counters.
    let fix = match SimArtifacts::in_temp() {
        Ok(f) => f,
        Err(e) => {
            println!("  (skipping /metrics scrape comparison: {e})");
            return;
        }
    };
    let yaml = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "solo"
predictors:
- name: solo
  experts: [s1]
  quantile: identity
server:
  workers: 2
"#;
    let pool = Arc::new(ModelPool::new(fix.manifest().unwrap()));
    let engine = Engine::build(&MuseConfig::from_yaml(yaml).unwrap(), pool).unwrap();
    for name in &names {
        let h = engine.tenants.resolve(name);
        engine.tenant_events.handle(h.index()).add(1);
    }
    let mut sink = 0usize;
    let r_snap = bench("scrape via snapshot clone (seed re-enactment)", 2, 20, || {
        let snap = engine.scored_events_snapshot();
        let mut body = String::with_capacity(snap.len() * 24);
        for (name, n) in &snap {
            muse::util::json::write_escaped(name, &mut body);
            body.push(':');
            muse::util::json::write_num(*n as f64, &mut body);
        }
        sink += body.len();
    });
    println!(
        "{}   ({:.2} ms/scrape)",
        r_snap.report(),
        r_snap.mean_ns / 1e6
    );
    let r_stream = bench("scrape via streamed /metrics (shard iteration)", 2, 20, || {
        sink += muse::server::metrics_json(&engine).len();
    });
    std::hint::black_box(sink);
    println!(
        "{}   ({:.2} ms/scrape, {:.2}x vs clone)",
        r_stream.report(),
        r_stream.mean_ns / 1e6,
        r_snap.mean_ns / r_stream.mean_ns
    );
}

/// Verification plane: the model-based suite's sequential oracle
/// (`muse::testkit` — one mutex around everything, linear-scan PWL,
/// per-event batch-1 inference) against the production engine on
/// identical traffic over the synthetic sim artifacts. The point of
/// the number is the *gap*: the oracle is deliberately naive so its
/// correctness is self-evident, and this section records what that
/// naivety costs — i.e. why it is a test-only component and why the
/// lock-free data plane exists at all.
fn bench_oracle_vs_engine() {
    section("verification plane: sequential oracle vs production engine (sim artifacts)");
    let fix = SimArtifacts::in_temp().unwrap();
    let yaml = r#"
routing:
  scoringRules:
  - description: "catch-all"
    condition: {}
    targetPredictorName: "duo"
predictors:
- name: duo
  experts: [s1, s2]
  quantile: identity
server:
  workers: 2
"#;
    let cfg = MuseConfig::from_yaml(yaml).unwrap();
    let (engine, oracle) = muse::testkit::build_pair(&fix, &cfg).unwrap();
    let mut wl = Workload::new(TenantProfile::new("acme", 5, 0.3, 0.1), 9);
    let reqs: Vec<ScoreRequest> = (0..256)
        .map(|i| ScoreRequest {
            intent: Intent {
                tenant: "acme".into(),
                ..Intent::default()
            },
            entity: format!("e{i}"),
            features: wl.next_event().features,
        })
        .collect();
    let mut i = 0usize;
    let r = bench("engine.score (lock-free data plane)", 128, 2_000, || {
        let req = &reqs[i % reqs.len()];
        i += 1;
        std::hint::black_box(engine.score(req).unwrap());
    });
    println!("  {}", r.report());
    let engine_ns = r.mean_ns;
    let mut j = 0usize;
    let r = bench("oracle.score (one mutex, linear scans)", 128, 2_000, || {
        let req = &reqs[j % reqs.len()];
        j += 1;
        std::hint::black_box(oracle.score(&req.intent, &req.features).unwrap());
    });
    println!("  {}", r.report());
    println!(
        "  oracle/engine mean ratio: {:.2}x (the price of obvious correctness)",
        r.mean_ns / engine_ns
    );
    engine.drain_shadows();
}

fn main() {
    bench_fused_vs_staged();
    bench_scoring_kernels();
    bench_lake_sharded_vs_global();
    bench_hot_counters();
    bench_tenant_state_plane();
    bench_lifecycle_overhead();
    bench_oracle_vs_engine();

    let Ok(manifest) = Manifest::load(Manifest::default_root()) else {
        println!("\nserving_bench: artifacts not built, skipping PJRT sections (run `make artifacts`)");
        return;
    };

    section("PJRT container inference by batch variant (model m1)");
    let pool = Arc::new(ModelPool::new(manifest));
    let h = pool.acquire("m1").unwrap();
    let d = h.feature_dim;
    for &b in &[1usize, 16, 64, 256] {
        let features = vec![0.1f32; b * d];
        let r = bench(&format!("m1 infer batch={b}"), 50, 2_000, || {
            std::hint::black_box(h.infer(&features, b).unwrap());
        });
        println!(
            "{}   ({:.2} us/event)",
            r.report(),
            r.mean_ns / 1e3 / b as f64
        );
    }
    pool.release("m1");

    section("engine: single-event end-to-end (router -> 3-expert ensemble -> T^Q)");
    let engine = Arc::new(Engine::build(&MuseConfig::from_yaml(CONFIG).unwrap(), pool).unwrap());
    muse::coordinator::warm_up(&engine, 300, 3).unwrap();
    let mut wl = Workload::new(TenantProfile::new("bank1", 9, 0.4, 0.1), 4);
    let mut events: Vec<Vec<f32>> = (0..4096).map(|_| wl.next_event().features).collect();
    let mut k = 0usize;
    let r_single = bench("engine.score (live path)", 100, 20_000, || {
        let req = ScoreRequest {
            intent: Intent {
                tenant: "bank1".into(),
                ..Intent::default()
            },
            entity: String::new(),
            features: std::mem::take(&mut events[k % 4096]),
        };
        let resp = engine.score(&req).unwrap();
        events[k % 4096] = req.features;
        std::hint::black_box(resp.score);
        k += 1;
    });
    println!("{}", r_single.report());

    section("engine throughput under concurrency (8 client threads)");
    let done = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..8 {
            let engine = Arc::clone(&engine);
            let done = Arc::clone(&done);
            scope.spawn(move || {
                let mut wl =
                    Workload::new(TenantProfile::new("bank1", 20 + c as u64, 0.4, 0.1), 5);
                for i in 0..4_000 {
                    let e = wl.next_event();
                    let req = ScoreRequest {
                        intent: Intent {
                            tenant: "bank1".into(),
                            ..Intent::default()
                        },
                        entity: format!("{c}-{i}"),
                        features: e.features,
                    };
                    engine.score(&req).unwrap();
                    done.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "  {} events in {:.2}s = {:.0} events/s (paper cluster avg: 4500 eps)\n  {}",
        done.load(Ordering::Relaxed),
        wall,
        done.load(Ordering::Relaxed) as f64 / wall,
        engine.live_latency.summary()
    );

    section("engine throughput under a promotion storm (8 clients, seamless-update check)");
    // Deploy a second live candidate and ping-pong bank1 between the
    // two predictors as fast as the control plane can publish
    // snapshots, while 8 client threads keep scoring. The contract:
    // zero failed requests, throughput within noise of the quiescent
    // run above (EXPERIMENTS.md "Contention").
    {
        let cp = ControlPlane::new(&engine);
        let done = Arc::new(AtomicU64::new(0));
        let live_clients = AtomicU64::new(8);
        let swaps = AtomicU64::new(0);
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..8 {
                let engine = Arc::clone(&engine);
                let done = Arc::clone(&done);
                let live_clients = &live_clients;
                scope.spawn(move || {
                    // Panic-safe: a dropped request must stop the
                    // promotion loop, not hang the scope join.
                    let _live = CountdownGuard(live_clients);
                    let mut wl =
                        Workload::new(TenantProfile::new("bank1", 40 + c as u64, 0.4, 0.1), 6);
                    for i in 0..4_000 {
                        let e = wl.next_event();
                        let req = ScoreRequest {
                            intent: Intent {
                                tenant: "bank1".into(),
                                ..Intent::default()
                            },
                            entity: format!("s{c}-{i}"),
                            features: e.features,
                        };
                        engine.score(&req).expect("request dropped during promotion");
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
            let cp = &cp;
            let live_clients = &live_clients;
            let swaps = &swaps;
            scope.spawn(move || {
                let mut k = 0u64;
                while live_clients.load(Ordering::Relaxed) > 0 {
                    let target = if k % 2 == 0 { "solo" } else { "trio" };
                    cp.promote("bank1", target).unwrap();
                    k += 1;
                }
                swaps.store(k, Ordering::Relaxed);
            });
        });
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  {} events in {:.2}s = {:.0} events/s under {} promotions ({:.0} swaps/s), zero drops",
            done.load(Ordering::Relaxed),
            wall,
            done.load(Ordering::Relaxed) as f64 / wall,
            swaps.load(Ordering::Relaxed),
            swaps.load(Ordering::Relaxed) as f64 / wall
        );
        // Restore the catch-all target for the batch section below.
        cp.promote("bank1", "trio").unwrap();
        engine.drain_shadows();
    }

    section("end-to-end batch scoring (score_batch, multi-tenant mix)");
    {
        let report = run_batch_mix(
            &engine,
            &BatchMixConfig {
                tenants: vec![
                    (TenantProfile::new("bank1", 9, 0.4, 0.1), 3.0),
                    (TenantProfile::new("bank2", 11, 0.4, 0.1), 1.0),
                ],
                batch_size: 256,
                batches: 64,
                seed: 9,
            },
        )
        .unwrap();
        let per_event_ns = report.wall_secs * 1e9 / report.events as f64;
        println!(
            "  {} events in {} batches of 256: {:.0} events/s ({:.0} ns/event; single-event live path: {:.0} ns/event => {:.1}x)",
            report.events,
            report.batches,
            report.events_per_sec,
            per_event_ns,
            r_single.mean_ns,
            r_single.mean_ns / per_event_ns
        );
        for (t, n) in &report.per_tenant {
            println!("    tenant {t}: {n} events");
        }
        engine.drain_shadows();
    }

    section("registry ops (dedup bookkeeping)");
    let pool2 = engine.registry.pool();
    // Hold one reference so the bench measures refcounting, not
    // container spawn/compile.
    let _anchor = pool2.acquire("m2").unwrap();
    println!(
        "{}",
        bench("pool acquire+release (warm container)", 10, 50_000, || {
            let h = pool2.acquire("m2").unwrap();
            std::hint::black_box(&h);
            pool2.release("m2");
        })
        .report()
    );
    pool2.release("m2");
}
