//! Cold start (Section 2.4 + 3.1): onboard a brand-new tenant with no
//! historical data. The predictor serves from the first transaction
//! using the Beta-mixture default transformation T^Q_v0; live
//! (unlabeled) traffic accumulates; once the Eq. 5 sample-size gate
//! opens, a custom T^Q_v1 is fitted and installed — and the score
//! distribution snaps onto the target reference.
//!
//! ```bash
//! make artifacts && cargo run --release --example cold_start
//! ```

use anyhow::Result;
use muse::config::{Intent, MuseConfig};
use muse::coordinator::{ControlPlane, Engine, ScoreRequest};
use muse::runtime::{Manifest, ModelPool};
use muse::simulator::{TenantProfile, Workload};
use muse::transforms::{quantile_fit, ReferenceDistribution};
use muse::util::stats;
use std::sync::Arc;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "cold-start clients on the shared 8-expert ensemble"
    condition: {}
    targetPredictorName: "ensemble8"
predictors:
- name: ensemble8
  experts: [m1, m2, m3, m4, m5, m6, m7, m8]
  quantile: default
"#;

fn bin_report(label: &str, scores: &[f64], reference: &ReferenceDistribution) {
    let counts = stats::bin_counts(scores, 10);
    let target = reference.bin_shares(10);
    let total: u64 = counts.iter().sum();
    let errs: Vec<String> = counts
        .iter()
        .zip(&target)
        .map(|(&c, &t)| format!("{:+.0}%", 100.0 * (c as f64 / total as f64 - t) / t))
        .collect();
    println!("  {label:<28} per-bin rel. error: [{}]", errs.join(", "));
}

fn main() -> Result<()> {
    let manifest = Manifest::load(Manifest::default_root())?;
    let reference = ReferenceDistribution::fraud_default();

    let pool = Arc::new(ModelPool::new(manifest));
    let engine = Engine::build(&MuseConfig::from_yaml(CONFIG)?, pool)?;
    let cp = ControlPlane::new(&engine);

    println!("== Cold start: new tenant, zero history ==\n");

    // Day 0: derive T^Q_v0 from the experts' combined training data
    // (Beta-mixture prior, Eqs. 6-8) — no tenant data needed.
    let train = muse::util::dataset::Dataset::load(
        &Manifest::load(Manifest::default_root())?.dataset("train_pool")?.path,
    )?;
    let fit = cp.fit_default_quantile("ensemble8", &train, &reference, &Default::default())?;
    println!(
        "day 0: default T^Q_v0 installed (Beta-mixture prior over {} training scores, {} knots)",
        train.n,
        fit.source_quantiles().len()
    );

    // Onboarding: the tenant scores from its first transaction.
    let mut wl = Workload::new(TenantProfile::new("newbank", 4242, 0.6, 0.0), 99);
    let mut v0_scores = vec![];
    for i in 0..12_000 {
        let e = wl.next_event();
        let resp = engine.score(&ScoreRequest {
            intent: Intent {
                tenant: "newbank".into(),
                ..Intent::default()
            },
            entity: format!("e{i}"),
            features: e.features,
        })?;
        v0_scores.push(resp.score);
    }
    println!("onboarding: {} events scored under T^Q_v0 (value from transaction #1)", v0_scores.len());
    bin_report("T^Q_v0 (default)", &v0_scores, &reference);

    // Eq. 5 gate: how much data do we need for a custom fit?
    let (a, delta, z) = (0.01, 0.2, 1.96);
    let need = quantile_fit::required_samples(a, delta, z)?;
    let have = engine.lake.raw_scores("newbank", "ensemble8").len();
    println!(
        "\nEq. 5 gate: alert rate {a}, rel. error {delta}, z={z} -> need {need} samples (have {have})"
    );

    // Fit + install the custom transformation once the gate opens.
    let map = cp.fit_custom_quantile("ensemble8", "newbank", &reference, a, delta, z)?;
    println!("custom T^Q_v1 fitted from live unlabeled traffic and installed atomically");
    let _ = map;

    // Post-update traffic follows the target reference.
    let mut v1_scores = vec![];
    for i in 0..12_000 {
        let e = wl.next_event();
        let resp = engine.score(&ScoreRequest {
            intent: Intent {
                tenant: "newbank".into(),
                ..Intent::default()
            },
            entity: format!("f{i}"),
            features: e.features,
        })?;
        v1_scores.push(resp.score);
    }
    bin_report("T^Q_v1 (custom)", &v1_scores, &reference);

    // Alert-rate stability at a client threshold.
    let threshold = reference.mixture.quantile(0.99);
    println!(
        "\nclient threshold at ref q99 ({threshold:.3}): alert rate v0 = {:.3}%, v1 = {:.3}% (target 1%)",
        100.0 * v0_scores.iter().filter(|&&s| s >= threshold).count() as f64 / v0_scores.len() as f64,
        100.0 * v1_scores.iter().filter(|&&s| s >= threshold).count() as f64 / v1_scores.len() as f64,
    );
    println!("\ntenant-side configuration changes: none (same intent throughout)");
    Ok(())
}
