//! Saturation smoke run: the `Engine::score` scaling ramp on the
//! synthetic sim-dialect artifacts (no `make artifacts` needed — this
//! is the CI smoke test for the lock-free observation plane).
//!
//! ```text
//! cargo run --release --example saturation
//! ```
//!
//! Ramps worker threads 1 → 8 over a fixed two-tenant mix, printing
//! events/s and p50/p99 per level. While it runs, the scenario
//! cross-checks the sharded data lake's merged per-pair counts
//! against the drivers' own sequential tallies — any lost, torn or
//! double-counted event exits non-zero, so CI gates on the
//! observation plane's correctness under real concurrency, not just
//! its speed.

use anyhow::{ensure, Result};
use muse::config::MuseConfig;
use muse::coordinator::Engine;
use muse::runtime::{ModelPool, SimArtifacts};
use muse::simulator::{run_saturation, SaturationConfig};
use std::sync::Arc;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "duo"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "solo"
predictors:
- name: duo
  experts: [s1, s2]
  quantile: identity
- name: solo
  experts: [s3]
  quantile: identity
server:
  workers: 4
  maxBatchDelayUs: 50
"#;

fn main() -> Result<()> {
    let fix = SimArtifacts::in_temp()?;
    eprintln!(
        "saturation: synthetic sim-dialect artifacts at {}",
        fix.root().display()
    );
    let pool = Arc::new(ModelPool::new(fix.manifest()?));
    let engine = Engine::build(&MuseConfig::from_yaml(CONFIG)?, pool)?;

    let report = run_saturation(&engine, &SaturationConfig::default())?;
    println!("{}", report.render());

    // The oracle cross-checks already ran inside the scenario; what
    // is left to gate on is shape: every level produced traffic and
    // the race diagnostics stayed clean.
    ensure!(report.levels.len() == 4, "ramp did not complete");
    ensure!(
        report.levels.iter().all(|l| l.events_per_sec > 0.0),
        "a ramp level produced no throughput"
    );
    ensure!(
        engine.lake.forced_overwrites() == 0 && engine.lake.lost_appends() == 0,
        "lock-free lake hit a pathological race on a healthy run"
    );
    engine.drain_shadows();
    println!("saturation: OK — oracle-exact observation plane under a 1->8 thread ramp");
    Ok(())
}
