//! Adversarial-drift scenario matrix smoke run: every drift regime ×
//! both calibration strategies, end to end through the real engine +
//! lifecycle controller, on the synthetic sim-dialect artifacts.
//!
//! ```text
//! cargo run --release --example drift_matrix
//! # replay a failing run exactly:
//! MUSE_DRIFT_MATRIX_SEED=0x4D415452 cargo run --release --example drift_matrix
//! ```
//!
//! Each cell builds its own engine, calibrates tenants through the
//! Eq. 5 gate (or deliberately not, for the onboarding storm), injects
//! its drift regime, and scores alert-rate stability + fraud recall at
//! the reference's fixed (1-a) quantile. The per-cell invariants
//! (quantile-map refuses the exact-tie attack on the degenerate-grid
//! gate, full-range keeps fitting, cold-start mixtures land before
//! Eq. 5, no lost feed appends, …) are enforced inside
//! `run_drift_matrix`; this binary adds the cross-cell checks and
//! exits non-zero on any failure, so CI actually gates on it.

use anyhow::{ensure, Result};
use muse::simulator::{run_drift_matrix, DriftMatrixConfig};

fn main() -> Result<()> {
    let cfg = DriftMatrixConfig::default();
    eprintln!(
        "drift_matrix: {} cells x {} strategies, seed 0x{:X}",
        cfg.cells.len(),
        cfg.strategies.len(),
        cfg.seed
    );
    let report = run_drift_matrix(&cfg)?;
    println!("{}", report.render());

    let expected = cfg.cells.len() * cfg.strategies.len();
    ensure!(
        report.outcomes.len() == expected,
        "{} outcomes for {} cells x strategies",
        report.outcomes.len(),
        expected
    );
    for o in &report.outcomes {
        ensure!(o.events_total > 0, "empty cell: {o:?}");
        ensure!(o.dropped_samples == 0, "lost appends: {o:?}");
        ensure!(
            o.before.events > 0 && o.during.events > 0 && o.after.events > 0,
            "missing phase metrics: {o:?}"
        );
    }
    // The headline A/B: under the exact-tie fast attack the empirical
    // refit is refused (typed degenerate-grid error), the full-range
    // mixture is not.
    let refused: Vec<&str> = report
        .outcomes
        .iter()
        .filter(|o| o.refit_refused)
        .map(|o| o.strategy)
        .collect();
    ensure!(
        refused.contains(&"quantileMap") && !refused.contains(&"fullRange"),
        "degeneracy gate did not split the strategies: refused = {refused:?}"
    );
    println!(
        "drift_matrix: OK — {} cells, {} events, both strategies through the real promote path",
        report.outcomes.len(),
        report.events_total
    );
    Ok(())
}
