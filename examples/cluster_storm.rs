//! Cluster-storm smoke run: Zipf multi-tenant traffic over an
//! N-node `MuseCluster` racing continuous two-phase publishes, with
//! one node killed mid-flip and a replacement joining by committed-log
//! replay — on synthetic sim-dialect artifacts (no `make artifacts`
//! needed; this is the CI smoke test for the cluster plane).
//!
//! ```text
//! cargo run --release --example cluster_storm
//! ```
//!
//! While it runs, the scenario asserts cluster-wide seamlessness:
//! zero dropped requests, zero torn (mixed-version) scores — every
//! response's predictor matches the control plane's recorded
//! assignment at some committed epoch inside the response's
//! attribution window — and epoch-exact accounting (driver tallies ==
//! non-shadow lake multiset summed over every node ever created,
//! including the crashed one). Any violation exits non-zero.
//! `MUSE_CLUSTER_EVENTS` overrides the call count and
//! `MUSE_CLUSTER_NODES` the node count.

use anyhow::{ensure, Result};
use muse::runtime::SimArtifacts;
use muse::simulator::{run_cluster_storm, ClusterStormConfig};

fn main() -> Result<()> {
    let calls = std::env::var("MUSE_CLUSTER_EVENTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);
    let nodes = std::env::var("MUSE_CLUSTER_NODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6)
        .clamp(4, 8);
    let fix = SimArtifacts::in_temp()?;
    eprintln!(
        "cluster_storm: synthetic sim-dialect artifacts at {}",
        fix.root().display()
    );

    let cfg = ClusterStormConfig {
        nodes,
        calls,
        promotions: 24,
        ..ClusterStormConfig::default()
    };
    let report = run_cluster_storm(&fix, &cfg)?;
    println!("{}", report.render());

    // The seamlessness and conservation checks already ran inside the
    // scenario; gate on shape: the storm really exercised the failure
    // schedule and the flip tail stayed measurable.
    ensure!(report.crashes == 1, "expected the mid-flip crash to fire");
    ensure!(
        report.joins == (nodes + 1) as u64,
        "expected the mid-storm join on top of the initial set"
    );
    ensure!(
        report.nodes_serving_final == nodes,
        "membership should end where it started (one crash, one join)"
    );
    ensure!(report.events_total >= calls as u64, "driven fewer events than calls");
    ensure!(report.flip_p99_ms >= 0.0, "flip latency must be reported");
    println!(
        "cluster_storm: OK — {} nodes, {} events, epoch {}, zero torn scores",
        report.nodes_initial, report.events_total, report.committed_epoch
    );
    Ok(())
}
