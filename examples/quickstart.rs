//! Quickstart: build an engine from a declarative config, score a few
//! multi-tenant events end to end, and inspect the routing decisions.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use muse::config::{Intent, MuseConfig};
use muse::coordinator::{Engine, ScoreRequest};
use muse::runtime::{Manifest, ModelPool};
use muse::simulator::{TenantProfile, Workload};
use std::sync::Arc;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 gets a dedicated 2-expert ensemble"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "bank1-predictor-v1"
  - description: "everyone else on the shared global predictor"
    condition: {}
    targetPredictorName: "global-predictor"
  shadowRules:
  - description: "evaluate the expanded ensemble in shadow for bank1"
    condition:
      tenants: ["bank1"]
    targetPredictorNames: ["bank1-predictor-v2"]
predictors:
- name: bank1-predictor-v1
  experts: [m1, m2]
  quantile: identity
- name: bank1-predictor-v2
  experts: [m1, m2, m3]
  quantile: identity
- name: global-predictor
  experts: [m1]
  quantile: identity
"#;

fn main() -> Result<()> {
    // 1. Load the AOT artifacts (built once by `make artifacts`).
    let manifest = Manifest::load(Manifest::default_root())?;
    println!(
        "loaded manifest: {} models, {} datasets",
        manifest.models.len(),
        manifest.datasets.len()
    );

    // 2. Build the engine: predictors deploy against the shared
    //    container pool — note p1 and p2 share m1, m2.
    let pool = Arc::new(ModelPool::new(manifest));
    let engine = Engine::build(&MuseConfig::from_yaml(CONFIG)?, pool)?;
    let stats = engine.registry.stats();
    println!(
        "deployed {} predictors over {} physical containers ({} logical refs)",
        stats.predictors, stats.pool.live_containers, stats.model_references
    );

    // 3. Score events for two tenants. Clients send an *intent*, never
    //    a model name.
    for tenant in ["bank1", "fintech-x"] {
        let mut wl = Workload::new(TenantProfile::new(tenant, 42, 0.4, 0.0), 7);
        for i in 0..3 {
            let event = wl.next_event();
            let resp = engine.score(&ScoreRequest {
                intent: Intent {
                    tenant: tenant.to_string(),
                    ..Intent::default()
                },
                entity: format!("{tenant}-card-{i}"),
                features: event.features,
            })?;
            println!(
                "tenant={tenant:<10} -> predictor={:<20} score={:.4} shadows={}",
                resp.predictor, resp.score, resp.shadow_count
            );
        }
    }

    // 4. Shadow traffic landed in the data lake without affecting the
    //    client responses.
    engine.drain_shadows();
    let counts = engine.lake.counts();
    println!("\ndata lake:");
    for ((tenant, predictor, shadow), n) in counts {
        println!(
            "  tenant={tenant:<10} predictor={predictor:<20} shadow={shadow:<5} records={n}"
        );
    }
    println!("\nlive latency: {}", engine.live_latency.summary());
    Ok(())
}
