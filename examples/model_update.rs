//! The Fig. 3 model lifecycle, end to end, with zero client changes:
//! shadow-deploy the expanded ensemble, validate it on mirrored
//! production traffic, refit its quantile transformation, promote it
//! to live, and decommission the old predictor — while the client
//! keeps sending the same intent the whole time.
//!
//! ```bash
//! make artifacts && cargo run --release --example model_update
//! ```

use anyhow::Result;
use muse::config::{Intent, MuseConfig, PredictorConfig, QuantileMode};
use muse::coordinator::{ControlPlane, Engine, ScoreRequest};
use muse::runtime::{Manifest, ModelPool};
use muse::simulator::{TenantProfile, Workload};
use muse::transforms::{QuantileMap, ReferenceDistribution};
use std::sync::Arc;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 on the incumbent ensemble"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "p1"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "p1"
predictors:
- name: p1
  experts: [m1, m2]
  quantile: identity
"#;

fn client_burst(engine: &Engine, wl: &mut Workload, n: usize) -> Result<Vec<f64>> {
    let mut scores = Vec::with_capacity(n);
    for i in 0..n {
        let e = wl.next_event();
        let resp = engine.score(&ScoreRequest {
            intent: Intent {
                tenant: "bank1".into(),
                ..Intent::default()
            },
            entity: format!("e{i}"),
            features: e.features,
        })?;
        scores.push(resp.score);
    }
    engine.drain_shadows();
    Ok(scores)
}

fn main() -> Result<()> {
    let manifest = Manifest::load(Manifest::default_root())?;
    let pool = Arc::new(ModelPool::new(manifest));
    let engine = Engine::build(&MuseConfig::from_yaml(CONFIG)?, pool)?;
    let cp = ControlPlane::new(&engine);
    let reference = ReferenceDistribution::fraud_default();
    let mut wl = Workload::new(TenantProfile::new("bank1", 31, 0.4, 0.4), 3);

    println!("== Fig. 3 lifecycle: {{m1,m2}} -> {{m1,m2,m3}} with zero client changes ==\n");
    let stats = |engine: &Engine| {
        let s = engine.registry.stats();
        format!("predictors={} containers={}", s.predictors, s.pool.live_containers)
    };
    println!("t0  baseline: {}", stats(&engine));

    // Phase 1: steady state on p1.
    client_burst(&engine, &mut wl, 500)?;
    println!("t1  500 live events served by p1");

    // Phase 2: shadow-deploy p2 (adds the m3 specialist).
    let p2 = PredictorConfig {
        name: "p2".into(),
        experts: vec!["m1".into(), "m2".into(), "m3".into()],
        weights: vec![1.0; 3],
        quantile_mode: QuantileMode::Custom,
        reference: "fraud-default".into(),
        posterior_correction: true,
    };
    cp.shadow_deploy(&p2, "bank1", QuantileMap::identity(1025)?.shared())?;
    println!("t2  p2 shadow-deployed: {} (m1, m2 reused — only m3 is new)", stats(&engine));

    // Phase 3: mirror production traffic; fit p2's tenant T^Q from the
    // shadow scores in the data lake, gated by Eq. 5 (a=2%, delta=0.2,
    // z=1.96 -> ~4.7k samples required).
    client_burst(&engine, &mut wl, 5_000)?;
    let map = cp.fit_custom_quantile("p2", "bank1", &reference, 0.02, 0.2, 1.96)?;
    println!(
        "t3  5000 shadow events collected; tenant T^Q fitted ({} knots, Eq.5-gated)",
        map.source_quantiles().len()
    );

    // Phase 4: validate the shadow's final-score distribution on
    // traffic scored *after* the custom transformation took effect
    // (the pre-fit shadow records went through the identity T^Q).
    engine.lake.purge_predictor("p2");
    client_burst(&engine, &mut wl, 2_000)?;
    let v = cp.validate_shadow("p2", "bank1", &reference, 1_000, 0.10)?;
    println!(
        "t4  shadow validation: {} samples, max bin deviation {:.3} -> {}",
        v.samples,
        v.max_bin_deviation,
        if v.pass { "PASS" } else { "HOLD" }
    );

    // Phase 5: promote. The client keeps sending the same intent.
    cp.promote("bank1", "p2")?;
    let resolution = engine.router.resolve(&Intent {
        tenant: "bank1".into(),
        ..Intent::default()
    })?;
    println!("t5  promoted: bank1 now resolves to '{}' (shadows: {:?})", resolution.live, resolution.shadows);
    client_burst(&engine, &mut wl, 500)?;

    // Phase 6: decommission p1; shared containers survive for p2.
    cp.decommission("p1")?;
    println!("t6  p1 decommissioned: {}", stats(&engine));
    let final_scores = client_burst(&engine, &mut wl, 500)?;
    println!(
        "t7  client still scoring uninterrupted (last mean score {:.4})",
        final_scores.iter().sum::<f64>() / final_scores.len() as f64
    );
    println!("\nclient-side changes required: none");
    Ok(())
}
