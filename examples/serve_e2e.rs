//! End-to-end serving driver (docs/ARCHITECTURE.md deliverable): boots the full
//! MUSE stack — real AOT-compiled models on PJRT containers, intent
//! router, transformations, HTTP front end with warm-up gating — then
//! drives a batched multi-tenant workload over HTTP and in-process,
//! reporting throughput and latency against the paper's SLOs
//! (30ms p99, 150ms p99.9).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use anyhow::Result;
use muse::config::{Intent, MuseConfig};
use muse::coordinator::{Engine, ScoreRequest};
use muse::metrics::LatencyHistogram;
use muse::runtime::{Manifest, ModelPool};
use muse::server::http::http_request;
use muse::simulator::{TenantProfile, Workload};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1: full 3-expert ensemble"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "trio"
  - description: "bank2: single specialist"
    condition:
      tenants: ["bank2"]
    targetPredictorName: "solo"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "trio"
  shadowRules:
  - description: "shadow the 8-expert ensemble for bank1"
    condition:
      tenants: ["bank1"]
    targetPredictorNames: ["wide"]
predictors:
- name: trio
  experts: [m1, m2, m3]
  quantile: identity
- name: solo
  experts: [m4]
  quantile: identity
- name: wide
  experts: [m1, m2, m3, m4, m5, m6, m7, m8]
  quantile: identity
server:
  workers: 8
"#;

fn main() -> Result<()> {
    let manifest = Manifest::load(Manifest::default_root())?;
    let pool = Arc::new(ModelPool::new(manifest));
    let engine = Arc::new(Engine::build(&MuseConfig::from_yaml(CONFIG)?, pool)?);
    let stats = engine.registry.stats();
    println!(
        "== MUSE end-to-end driver ==\npredictors={} containers={} (dedup: wide reuses trio+solo experts)",
        stats.predictors, stats.pool.live_containers
    );

    // --- Phase 1: HTTP path (includes warm-up before readiness) -----
    let t0 = Instant::now();
    let (addr, _ready, _handle) =
        muse::server::spawn_server(Arc::clone(&engine), "127.0.0.1:0", 8, 300)?;
    println!("server ready on {addr} after {:.2}s (incl. warm-up)", t0.elapsed().as_secs_f64());

    let http_lat = Arc::new(LatencyHistogram::new());
    let n_http = 2_000usize;
    let clients = 8usize;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = addr.clone();
            let lat = Arc::clone(&http_lat);
            scope.spawn(move || {
                let tenant = ["bank1", "bank2", "other"][c % 3];
                let mut wl = Workload::new(TenantProfile::new(tenant, c as u64, 0.4, 0.1), 55);
                for i in 0..n_http / clients {
                    let e = wl.next_event();
                    let feats: Vec<String> = e.features.iter().map(|f| format!("{f}")).collect();
                    let payload = format!(
                        r#"{{"tenant":"{tenant}","entity":"e{c}-{i}","features":[{}]}}"#,
                        feats.join(",")
                    );
                    let s = Instant::now();
                    let (status, _body) =
                        http_request(&addr, "POST", "/score", &payload).expect("http");
                    assert_eq!(status, 200);
                    lat.record(s.elapsed().as_nanos() as u64);
                }
            });
        }
    });
    let http_wall = t0.elapsed().as_secs_f64();
    println!(
        "\nHTTP path: {} requests in {:.2}s = {:.0} req/s\n  {}",
        n_http,
        http_wall,
        n_http as f64 / http_wall,
        http_lat.summary()
    );

    // --- Phase 2: in-process hot path at full pressure --------------
    let done = Arc::new(AtomicU64::new(0));
    let lat = Arc::new(LatencyHistogram::new());
    let n_inproc = 20_000usize;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let engine = Arc::clone(&engine);
            let done = Arc::clone(&done);
            let lat = Arc::clone(&lat);
            scope.spawn(move || {
                let tenant = ["bank1", "bank2", "other"][c % 3];
                let mut wl = Workload::new(TenantProfile::new(tenant, 10 + c as u64, 0.4, 0.1), 77);
                for i in 0..n_inproc / clients {
                    let e = wl.next_event();
                    let req = ScoreRequest {
                        intent: Intent {
                            tenant: tenant.into(),
                            ..Intent::default()
                        },
                        entity: format!("p{c}-{i}"),
                        features: e.features,
                    };
                    let s = Instant::now();
                    engine.score(&req).expect("score");
                    lat.record(s.elapsed().as_nanos() as u64);
                    done.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let eps = done.load(Ordering::Relaxed) as f64 / wall;
    println!(
        "\nin-process hot path: {} events in {:.2}s = {:.0} events/s\n  {}",
        done.load(Ordering::Relaxed),
        wall,
        eps,
        lat.summary()
    );

    engine.drain_shadows();
    let shadow_records = engine
        .lake
        .counts()
        .iter()
        .filter(|((_, _, shadow), _)| *shadow)
        .map(|(_, n)| n)
        .sum::<usize>();
    println!("\nshadow records mirrored to the data lake: {shadow_records}");

    let p99 = lat.percentile_ns(99.0) as f64 / 1e6;
    let p999 = lat.percentile_ns(99.9) as f64 / 1e6;
    println!("\n== SLO verdict (paper: p99<30ms, p99.9<150ms, >1000 eps) ==");
    println!("  (stress profile: bank1 traffic is 100% shadow-mirrored onto an");
    println!("   8-expert ensemble — 11 model inferences per event; the SLO");
    println!("   exhibit without shadow amplification is `muse repro headline`)");
    println!("  p99    = {p99:.2} ms   -> {}", if p99 < 30.0 { "PASS" } else { "MISS" });
    println!("  p99.9  = {p999:.2} ms  -> {}", if p999 < 150.0 { "PASS" } else { "MISS" });
    println!("  eps    = {eps:.0}      -> {}", if eps > 1000.0 { "PASS" } else { "MISS" });
    Ok(())
}
