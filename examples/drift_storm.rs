//! Drift-storm smoke run: the lifecycle autopilot end to end, on the
//! synthetic sim-dialect artifacts (no `make artifacts` needed — this
//! is the CI smoke test for the subsystem).
//!
//! ```text
//! cargo run --release --example drift_storm
//! ```
//!
//! Builds an engine with the autopilot enabled for tenant `acme`,
//! calibrates, injects a fraud-wave distribution shift, and verifies
//! the controller detects → refits from sketches → shadow-validates →
//! promotes with zero manual control-plane calls, restoring the
//! tenant's alert rate to within 10% relative error of target.
//! Exits non-zero if any of that fails, so CI actually gates on it.

use anyhow::{ensure, Result};
use muse::config::MuseConfig;
use muse::coordinator::Engine;
use muse::runtime::{ModelPool, SimArtifacts};
use muse::simulator::{run_drift_storm, DriftStormConfig};
use std::sync::Arc;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "acme dedicated"
    condition:
      tenants: ["acme"]
    targetPredictorName: "duo"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "solo"
predictors:
- name: duo
  experts: [s1, s2]
  quantile: custom
- name: solo
  experts: [s3]
  quantile: identity
server:
  workers: 2
  maxBatchEvents: 1024
  lakeMaxRecords: 200000
lifecycle:
  enabled: true
  tenants: ["acme"]
  autoDiscover: false
  sketchK: 4096
  alertRate: 0.1
  delta: 0.05
  minDriftSamples: 512
  minValidationSamples: 512
  validationTolerance: 0.08
  cooldownTicks: 4
"#;

fn main() -> Result<()> {
    let fix = SimArtifacts::in_temp()?;
    eprintln!(
        "drift_storm: synthetic sim-dialect artifacts at {}",
        fix.root().display()
    );
    let pool = Arc::new(ModelPool::new(fix.manifest()?));
    let engine = Engine::build(&MuseConfig::from_yaml(CONFIG)?, pool)?;

    let report = run_drift_storm(&engine, &DriftStormConfig::default())?;
    println!("{}", report.render());

    ensure!(report.promotions >= 1, "no autonomous promotion");
    ensure!(
        report.rel_err_before <= 0.10,
        "pre-storm alert error {:.1}% > 10%",
        100.0 * report.rel_err_before
    );
    ensure!(
        report.rel_err_during >= 0.5,
        "storm too weak ({:.1}%)",
        100.0 * report.rel_err_during
    );
    ensure!(
        report.rel_err_after <= 0.10,
        "post-recovery alert error {:.1}% > 10%",
        100.0 * report.rel_err_after
    );
    engine.drain_shadows();
    println!("drift_storm: OK — autopilot restored the alert rate autonomously");
    Ok(())
}
