//! Connection-storm smoke run: >= 5k concurrent keep-alive HTTP
//! connections against the event-driven ingress plane, on synthetic
//! sim-dialect artifacts (no `make artifacts` needed — this is the
//! CI smoke test for the reactor).
//!
//! ```text
//! ulimit -n 32768
//! cargo run --release --example connection_storm
//! ```
//!
//! One client thread multiplexes every connection through the same
//! epoll poller the server uses; the seed's thread-per-connection
//! front end could not hold this load at all. While it runs, the
//! scenario cross-checks driver-side response tallies against the
//! data lake, the wait-free request gauge and the `ingress_*`
//! counters — any lost or double-counted request exits non-zero.
//! `MUSE_STORM_CONNS` overrides the connection count (e.g. for local
//! machines with low fd limits).

use anyhow::{ensure, Result};
use muse::config::MuseConfig;
use muse::coordinator::Engine;
use muse::runtime::{ModelPool, SimArtifacts};
use muse::simulator::{run_connection_storm, ConnectionStormConfig};
use std::sync::Arc;

const CONFIG: &str = r#"
routing:
  scoringRules:
  - description: "bank1 dedicated"
    condition:
      tenants: ["bank1"]
    targetPredictorName: "duo"
  - description: "catch-all"
    condition: {}
    targetPredictorName: "solo"
predictors:
- name: duo
  experts: [s1, s2]
  quantile: identity
- name: solo
  experts: [s3]
  quantile: identity
server:
  workers: 4
  maxBatchDelayUs: 50
"#;

fn main() -> Result<()> {
    let connections = std::env::var("MUSE_STORM_CONNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5_000);
    let fix = SimArtifacts::in_temp()?;
    eprintln!(
        "connection_storm: synthetic sim-dialect artifacts at {}",
        fix.root().display()
    );
    let pool = Arc::new(ModelPool::new(fix.manifest()?));
    let engine = Arc::new(Engine::build(&MuseConfig::from_yaml(CONFIG)?, pool)?);

    let cfg = ConnectionStormConfig {
        connections,
        requests_per_connection: 2,
        ..ConnectionStormConfig::default()
    };
    let report = run_connection_storm(Arc::clone(&engine), &cfg)?;
    println!("{}", report.render());

    // The conservation checks already ran inside the scenario; gate
    // on shape: the storm really held the concurrency it claims, the
    // tail is measurable and the race diagnostics stayed clean.
    ensure!(
        report.peak_open == connections,
        "storm opened {} of {connections} connections",
        report.peak_open
    );
    ensure!(report.p99_ms > 0.0, "p99 latency was not measured");
    ensure!(
        report.p99_ms < 10_000.0,
        "p99 {}ms: the reactor is stalling under concurrent load",
        report.p99_ms
    );
    ensure!(
        engine.lake.forced_overwrites() == 0 && engine.lake.lost_appends() == 0,
        "lock-free lake hit a pathological race on a healthy run"
    );
    println!(
        "connection_storm: OK — {} keep-alive connections, request-exact accounting",
        report.peak_open
    );
    Ok(())
}
