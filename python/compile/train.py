"""Build-time training of the MUSE expert models.

Trains the expert roster used by the paper's evaluation scenarios:

* ``m1`` (beta ~= 18%), ``m2`` (beta ~= 18%), ``m3`` (beta ~= 2%,
  specialised on the "new fraud pattern" P1) — the 3-model ensemble of
  Section 3.2 / Table 1;
* ``m4``..``m8`` — additional heterogeneous experts so that, together
  with m1..m3, they form the 8-model ensemble of Section 3.1 (Fig. 4).

Each expert trains on the provider's combined multi-tenant pool with
its own majority-class undersampling ratio ``beta`` — the bias that
Posterior Correction (Eq. 3) later reverses. m3 trains on a P1-heavy
pool, modelling a specialist deployed to counter a new attack.

Run via ``python -m compile.train`` (or through ``aot.py``, which
invokes :func:`train_all`). Pure CPU-jax; deterministic seeds.
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np

from . import datagen, model

POOL_SIZE = 240_000
POOL_SEED = 20_260_710


@dataclasses.dataclass(frozen=True)
class ExpertSpec:
    name: str
    arch: str  # "mlp1" | "mlp2"
    h: int
    h2: int
    beta: float  # negative-class undersampling ratio used in training
    seed: int
    pattern1_frac: float  # P1 share of fraud in this expert's pool
    steps: int = 700
    lr: float = 3e-3


# The roster. m3 is the P1 specialist with aggressive undersampling
# (two orders of magnitude, like the paper's beta ~= 2% expert).
EXPERTS: list[ExpertSpec] = [
    ExpertSpec("m1", "mlp1", 64, 0, beta=0.18, seed=11, pattern1_frac=0.08),
    ExpertSpec("m2", "mlp2", 64, 32, beta=0.18, seed=22, pattern1_frac=0.08),
    ExpertSpec("m3", "mlp1", 64, 0, beta=0.02, seed=33, pattern1_frac=0.85),
    ExpertSpec("m4", "mlp1", 48, 0, beta=0.10, seed=44, pattern1_frac=0.08),
    ExpertSpec("m5", "mlp2", 48, 24, beta=0.30, seed=55, pattern1_frac=0.08),
    ExpertSpec("m6", "mlp1", 32, 0, beta=0.05, seed=66, pattern1_frac=0.15),
    ExpertSpec("m7", "mlp1", 64, 0, beta=0.25, seed=77, pattern1_frac=0.08),
    ExpertSpec("m8", "mlp2", 64, 32, beta=0.08, seed=88, pattern1_frac=0.20),
]


def train_expert(spec: ExpertSpec) -> tuple[model.Params, dict]:
    """Train one expert; returns (params, metadata)."""
    x, y = datagen.generate_training_pool(
        POOL_SIZE, POOL_SEED + spec.seed, pattern1_frac=spec.pattern1_frac
    )
    xu, yu = datagen.undersample(x, y, spec.beta, seed=spec.seed * 7 + 1)
    params = model.init_params(
        jax.random.PRNGKey(spec.seed), spec.arch, datagen.FEATURE_DIM, spec.h, spec.h2
    )
    params, loss = model.fit(
        params, xu, yu, steps=spec.steps, batch=512, seed=spec.seed, lr=spec.lr
    )
    # Sanity: separation on the *original* (non-undersampled) pool.
    probs = np.asarray(model.expert_fwd_ref(x[:20_000], params))
    yv = y[:20_000]
    auc = _auc(probs, yv)
    meta = {
        "name": spec.name,
        "arch": spec.arch,
        "h": spec.h,
        "h2": spec.h2,
        "beta": spec.beta,
        "seed": spec.seed,
        "pattern1_frac": spec.pattern1_frac,
        "final_loss": loss,
        "train_pool_auc": auc,
        "undersampled_n": int(len(yu)),
        "undersampled_pos_rate": float(yu.mean()),
    }
    return params, meta


def _auc(scores: np.ndarray, labels: np.ndarray) -> float:
    """Rank-based AUC (Mann-Whitney)."""
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty(len(scores))
    ranks[order] = np.arange(1, len(scores) + 1)
    pos = labels == 1
    n_pos = int(pos.sum())
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    return float((ranks[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


def params_to_json(params: model.Params) -> list[dict]:
    return [
        {"w": np.asarray(w).tolist(), "b": np.asarray(b).tolist()} for w, b in params
    ]


def params_from_json(obj: list[dict]) -> model.Params:
    import jax.numpy as jnp

    return [
        (jnp.asarray(p["w"], jnp.float32), jnp.asarray(p["b"], jnp.float32))
        for p in obj
    ]


def train_all(weights_dir: str, force: bool = False) -> list[dict]:
    """Train every expert, writing weights + metadata JSON per expert.

    Skips experts whose weight files already exist (idempotent builds)
    unless ``force``.
    """
    os.makedirs(weights_dir, exist_ok=True)
    metas = []
    for spec in EXPERTS:
        path = os.path.join(weights_dir, f"{spec.name}.json")
        if os.path.exists(path) and not force:
            with open(path) as f:
                obj = json.load(f)
            metas.append(obj["meta"])
            continue
        params, meta = train_expert(spec)
        with open(path, "w") as f:
            json.dump({"meta": meta, "params": params_to_json(params)}, f)
        metas.append(meta)
        print(
            f"[train] {spec.name} arch={spec.arch} beta={spec.beta} "
            f"loss={meta['final_loss']:.4f} auc={meta['train_pool_auc']:.4f}"
        )
    return metas


def load_params(weights_dir: str, name: str) -> tuple[model.Params, dict]:
    with open(os.path.join(weights_dir, f"{name}.json")) as f:
        obj = json.load(f)
    return params_from_json(obj["params"]), obj["meta"]


if __name__ == "__main__":
    train_all("../artifacts/weights", force="--force" in __import__("sys").argv)
