"""Synthetic fraud-transaction generator (build-time substrate).

The paper evaluates MUSE on proprietary production streams (55B events
across dozens of financial institutions). We substitute a synthetic,
deterministic generator that preserves the properties the paper's
mechanisms react to (see DESIGN.md "Substitutions"):

* heavy class imbalance (fraud prior ~1.5%) -> undersampling during
  training -> posterior-correction bias (Eq. 3, Table 1);
* per-tenant covariate shift -> tenant-specific source quantiles
  (Section 2.3.3, Fig. 4);
* an injectable "new fraud pattern" that legacy experts detect poorly
  -> motivates the ensemble expansion of Fig. 6 and expert m3;
* slow concept drift within a period -> realistic, non-iid streams.

Feature model
-------------
``D = 24`` features. Legitimate events are drawn from a correlated
Gaussian background plus a log-normal "amount" channel. Fraud events
add a sparse mean-shift along one of two *patterns*:

* pattern ``P0`` ("classic") shifts dims 0..7,
* pattern ``P1`` ("new attack") shifts dims 8..15 with a weaker echo
  on dims 0..3, so legacy experts (trained mostly on P0) score it
  poorly while the specialist expert m3 (trained mostly on P1)
  separates it well.

Tenants apply an affine shift/scale drawn from a per-tenant seed,
modelling different client bases and integration schemas.

Everything is seeded and pure numpy so artifact builds are
reproducible bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

# ---------------------------------------------------------------------------
# Global constants (mirrored in rust/src/simulator/workload.rs)
# ---------------------------------------------------------------------------

FEATURE_DIM = 24
FRAUD_PRIOR = 0.015
AMOUNT_DIM = FEATURE_DIM - 1  # last feature is log-amount

# Sparse fraud mean-shifts per pattern (see module docstring).
_P0_DIMS = np.arange(0, 8)
_P1_DIMS = np.arange(8, 16)
_P1_ECHO_DIMS = np.arange(0, 4)

_P0_SHIFT = 1.15
_P1_SHIFT = 1.25
_P1_ECHO = 0.25

# Correlated background: x = L z with a mild banded correlation.
_CORR = 0.35

DATASET_MAGIC = 0x4D555345  # "MUSE"


@dataclasses.dataclass(frozen=True)
class TenantProfile:
    """Per-tenant covariate shift: x -> scale * x + shift."""

    name: str
    seed: int
    shift_scale: float = 0.45
    scale_jitter: float = 0.12
    fraud_rate: float = FRAUD_PRIOR
    pattern1_frac: float = 0.0  # fraction of fraud that is the new pattern

    def affine(self) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(self.seed)
        shift = rng.normal(0.0, self.shift_scale, size=FEATURE_DIM)
        scale = 1.0 + rng.normal(0.0, self.scale_jitter, size=FEATURE_DIM)
        # Keep the amount channel comparable across tenants.
        shift[AMOUNT_DIM] *= 0.25
        scale[AMOUNT_DIM] = 1.0
        return shift.astype(np.float32), np.abs(scale).astype(np.float32)


# The global training population: a blend of "integrated" tenants.
TRAIN_TENANTS = [TenantProfile(f"train-{i}", seed=1000 + i) for i in range(6)]

# Evaluation tenants used by the paper-exhibit harnesses.
CLIENT_A = TenantProfile("client-A", seed=4242, shift_scale=0.6, pattern1_frac=0.0)
CLIENT_B_PRE = TenantProfile("client-B", seed=7001, shift_scale=0.5, pattern1_frac=0.10)
CLIENT_B_POST = TenantProfile(
    "client-B", seed=7001, shift_scale=0.5, pattern1_frac=0.55
)


def _background(rng: np.random.Generator, n: int) -> np.ndarray:
    """Correlated Gaussian background + log-normal amount channel."""
    z = rng.standard_normal((n, FEATURE_DIM)).astype(np.float32)
    x = z.copy()
    # One-step banded correlation: x_i += corr * z_{i-1}.
    x[:, 1:] += _CORR * z[:, :-1]
    x[:, AMOUNT_DIM] = rng.lognormal(3.2, 1.1, size=n).astype(np.float32) / 100.0
    return x


def _apply_fraud(
    rng: np.random.Generator, x: np.ndarray, y: np.ndarray, pattern1_frac: float
) -> np.ndarray:
    """Shift the fraud rows along pattern P0 or P1 (in place)."""
    idx = np.flatnonzero(y == 1)
    if idx.size == 0:
        return x
    is_p1 = rng.random(idx.size) < pattern1_frac
    p0_idx = idx[~is_p1]
    p1_idx = idx[is_p1]
    jitter0 = rng.normal(1.0, 0.25, size=(p0_idx.size, 1)).astype(np.float32)
    jitter1 = rng.normal(1.0, 0.25, size=(p1_idx.size, 1)).astype(np.float32)
    x[np.ix_(p0_idx, _P0_DIMS)] += _P0_SHIFT * jitter0
    x[np.ix_(p1_idx, _P1_DIMS)] += _P1_SHIFT * jitter1
    x[np.ix_(p1_idx, _P1_ECHO_DIMS)] += _P1_ECHO * jitter1
    # Fraud skews to larger amounts.
    x[idx, AMOUNT_DIM] *= rng.lognormal(0.35, 0.3, size=idx.size).astype(np.float32)
    return x


def generate(
    n: int,
    seed: int,
    tenant: TenantProfile,
    drift: float = 0.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` events for ``tenant``.

    Returns ``(x, y)`` with ``x`` float32 ``[n, FEATURE_DIM]`` and ``y``
    float32 ``[n]`` in {0, 1}. ``drift`` linearly interpolates an extra
    mean shift over the stream, modelling slow concept drift.
    """
    rng = np.random.default_rng(seed)
    y = (rng.random(n) < tenant.fraud_rate).astype(np.float32)
    x = _background(rng, n)
    x = _apply_fraud(rng, x, y, tenant.pattern1_frac)
    shift, scale = tenant.affine()
    x = x * scale[None, :] + shift[None, :]
    if drift != 0.0:
        t = np.linspace(0.0, 1.0, n, dtype=np.float32)[:, None]
        drift_dir = np.random.default_rng(tenant.seed + 99).normal(
            0.0, 1.0, size=FEATURE_DIM
        )
        drift_dir = (drift_dir / np.linalg.norm(drift_dir)).astype(np.float32)
        x = x + drift * t * drift_dir[None, :]
    return x.astype(np.float32), y


def generate_training_pool(
    n: int, seed: int, pattern1_frac: float = 0.08
) -> tuple[np.ndarray, np.ndarray]:
    """The provider's combined multi-tenant training population."""
    per = n // len(TRAIN_TENANTS)
    xs, ys = [], []
    for i, t in enumerate(TRAIN_TENANTS):
        t = dataclasses.replace(t, pattern1_frac=pattern1_frac)
        x, y = generate(per, seed + 17 * i, t)
        xs.append(x)
        ys.append(y)
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = np.random.default_rng(seed + 777).permutation(len(y))
    return x[perm], y[perm]


def undersample(
    x: np.ndarray, y: np.ndarray, beta: float, seed: int
) -> tuple[np.ndarray, np.ndarray]:
    """Keep every positive, keep negatives with probability ``beta``.

    This is the training-time majority-class undersampling whose score
    bias the Posterior Correction (Eq. 3) reverses: the positive prior
    in the undersampled set rises from pi to pi / (pi + beta (1-pi)).
    """
    if not 0.0 < beta <= 1.0:
        raise ValueError(f"beta must be in (0, 1], got {beta}")
    rng = np.random.default_rng(seed)
    keep = (y == 1) | (rng.random(len(y)) < beta)
    return x[keep], y[keep]


# ---------------------------------------------------------------------------
# Binary dataset interchange with the rust side
# ---------------------------------------------------------------------------
# Layout (little endian):
#   u32 magic, u32 version, u64 n, u32 d, u32 reserved
#   f32 features [n*d] row-major, f32 labels [n]


def write_dataset(path: str, x: np.ndarray, y: np.ndarray) -> None:
    assert x.ndim == 2 and y.ndim == 1 and x.shape[0] == y.shape[0]
    x = np.ascontiguousarray(x, dtype="<f4")
    y = np.ascontiguousarray(y, dtype="<f4")
    with open(path, "wb") as f:
        f.write(struct.pack("<IIQII", DATASET_MAGIC, 1, x.shape[0], x.shape[1], 0))
        f.write(x.tobytes())
        f.write(y.tobytes())


def read_dataset(path: str) -> tuple[np.ndarray, np.ndarray]:
    with open(path, "rb") as f:
        magic, version, n, d, _ = struct.unpack("<IIQII", f.read(24))
        if magic != DATASET_MAGIC or version != 1:
            raise ValueError(f"bad dataset header in {path}")
        x = np.frombuffer(f.read(4 * n * d), dtype="<f4").reshape(n, d)
        y = np.frombuffer(f.read(4 * n), dtype="<f4")
    return x, y
