"""L2: the expert fraud models in JAX — forward pass and training step.

Each MUSE *expert* ``m_k`` is a small MLP over the transaction feature
vector. The serving forward pass (:func:`expert_fwd`) calls the L1
Pallas fused-MLP kernel so that the whole expert lowers into a single
HLO module (see ``aot.py``); training uses the pure-jnp oracle (no
tiling needed, and it keeps backward-mode AD simple).

Architectures (paper: heterogeneous ensembles; Section 2.2):
  * ``arch="mlp1"`` — 1 hidden layer (D -> H -> 1)
  * ``arch="mlp2"`` — 2 hidden layers (D -> H -> H2 -> 1)

Training: binary cross-entropy on logits, Adam (implemented inline —
this repo builds its substrates from scratch), majority-class
undersampling applied by ``train.py`` *before* batching, which is
exactly the bias that the Posterior Correction (Eq. 3) later reverses.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import fused_mlp as fused
from .kernels import ref

Params = list[tuple[jax.Array, jax.Array]]


def init_params(key, arch: str, d: int, h: int = 64, h2: int = 32) -> Params:
    """He-initialised parameters for an expert."""
    if arch == "mlp1":
        dims = [d, h, 1]
    elif arch == "mlp2":
        dims = [d, h, h2, 1]
    else:
        raise ValueError(f"unknown arch {arch!r}")
    params: Params = []
    for din, dout in zip(dims[:-1], dims[1:]):
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (din, dout), jnp.float32) * jnp.sqrt(2.0 / din)
        params.append((w, jnp.zeros((dout,), jnp.float32)))
    return params


def expert_fwd(x, params: Params):
    """Serving forward pass: probabilities via the Pallas fused kernel."""
    return fused.fused_mlp(x, params)


def expert_fwd_ref(x, params: Params):
    """Training/oracle forward pass (pure jnp)."""
    return ref.mlp_ref(x, params)


def bce_loss(params: Params, x, y, l2: float = 1e-4):
    """Mean binary cross-entropy on logits + L2 weight decay."""
    logits = ref.mlp_logits_ref(x, params)
    # Numerically stable BCE-with-logits.
    per = jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    reg = sum(jnp.sum(w * w) for w, _ in params)
    return per.mean() + l2 * reg


# ---------------------------------------------------------------------------
# Adam (from scratch; no optax dependency)
# ---------------------------------------------------------------------------


def adam_init(params: Params) -> dict[str, Any]:
    return {
        "m": [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in params],
        "v": [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in params],
        "t": jnp.zeros((), jnp.int32),
    }


@functools.partial(jax.jit, static_argnames=("lr", "b1", "b2", "eps"))
def train_step(params: Params, opt, x, y, lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    """One fwd/bwd Adam step. Returns (params, opt, loss)."""
    loss, grads = jax.value_and_grad(bce_loss)(params, x, y)
    t = opt["t"] + 1
    tf = t.astype(jnp.float32)
    bc1 = 1.0 - b1**tf
    bc2 = 1.0 - b2**tf

    new_params: Params = []
    new_m, new_v = [], []
    for (w, b), (gw, gb), (mw, mb), (vw, vb) in zip(params, grads, opt["m"], opt["v"]):
        mw = b1 * mw + (1 - b1) * gw
        mb = b1 * mb + (1 - b1) * gb
        vw = b2 * vw + (1 - b2) * gw * gw
        vb = b2 * vb + (1 - b2) * gb * gb
        w = w - lr * (mw / bc1) / (jnp.sqrt(vw / bc2) + eps)
        b = b - lr * (mb / bc1) / (jnp.sqrt(vb / bc2) + eps)
        new_params.append((w, b))
        new_m.append((mw, mb))
        new_v.append((vw, vb))
    return new_params, {"m": new_m, "v": new_v, "t": t}, loss


def fit(params: Params, x, y, steps: int, batch: int, seed: int, lr=3e-3):
    """Mini-batch Adam training loop. Returns (params, final_loss)."""
    key = jax.random.PRNGKey(seed)
    opt = adam_init(params)
    n = x.shape[0]
    loss = jnp.inf
    for _ in range(steps):
        key, bk = jax.random.split(key)
        idx = jax.random.randint(bk, (batch,), 0, n)
        params, opt, loss = train_step(params, opt, x[idx], y[idx], lr=lr)
    return params, float(loss)


def ensemble_fwd_ref(x, all_params: list[Params]):
    """Raw (uncorrected) scores of an ensemble: ``[B, K]``."""
    return jnp.stack([ref.mlp_ref(x, p) for p in all_params], axis=-1)
