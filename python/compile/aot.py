"""AOT compile path: JAX -> HLO *text* artifacts for the rust runtime.

This is the only place Python touches the system; it runs once at
``make artifacts`` and produces everything the self-contained rust
binary consumes:

* ``artifacts/models/{name}_b{B}.hlo.txt`` — each trained expert's
  fused forward pass (weights baked in as constants), one module per
  batch-size variant. One compiled PJRT executable per artifact is the
  "model container" the coordinator's registry shares across
  predictors (Section 2.2.1).
* ``artifacts/models/{name}_b{B}.sim.txt`` — the same expert in the
  ``muse-sim-hlo v1`` dialect for the vendored offline ``xla`` shim
  (``rust/vendor/xla``); this is what the manifest references, since
  the offline crate universe has no real PJRT bindings.
* ``artifacts/transform/transform_k{K}_b{B}.hlo.txt`` — the fused
  T^C -> A -> T^Q pipeline kernel for K-expert ensembles (batched /
  offline path; the rust hot path also implements the math natively).
* ``artifacts/data/*.bin`` — the evaluation datasets for the paper's
  exhibits (Figs. 4-6, Table 1). See DESIGN.md "Substitutions".
* ``artifacts/weights/*.json`` — trained weights + metadata.
* ``artifacts/manifest.json`` — the index the rust side parses.

Interchange format is HLO **text**, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, train
from .kernels import transform as tkern

BATCH_VARIANTS = [1, 16, 64, 256]
QUANTILE_POINTS = 1025  # N = 1024 segments
TRANSFORM_KS = [3, 8]
TRANSFORM_BATCHES = [64, 256]

# Evaluation datasets: (filename, tenant profile, n, seed, drift)
DATASETS = [
    ("train_pool", None, 60_000, 909, 0.0),
    ("client_a_live", datagen.CLIENT_A, 120_000, 555, 0.05),
    ("client_b_pre", datagen.CLIENT_B_PRE, 100_000, 661, 0.03),
    ("client_b_post", datagen.CLIENT_B_POST, 100_000, 662, 0.03),
    ("valid_m1", None, 40_000, 9091, 0.0),
    ("valid_m2", None, 40_000, 9092, 0.0),
    ("valid_m3", "m3pool", 40_000, 9093, 0.0),
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (the interchange).

    ``as_hlo_text(True)`` = print_large_constants: the default printer
    elides big literals as ``constant({...})``, which would silently
    zero the baked model weights when the rust side re-parses the text.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def lower_expert(params, batch: int) -> str:
    """Lower one expert's fused forward at a fixed batch size.

    Weights are closed over, so they are folded into the module as
    constants and the rust side only feeds features ``[B, D]``.
    """
    from . import model

    def fn(x):
        return (model.expert_fwd(x, params),)

    spec = jax.ShapeDtypeStruct((batch, datagen.FEATURE_DIM), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def to_sim_text(params, batch: int, d: int) -> str:
    """Emit one expert in the ``muse-sim-hlo v1`` dialect.

    The offline build environment vendors an API-compatible ``xla``
    shim (``rust/vendor/xla``) instead of real PJRT bindings, and the
    shim interprets this tiny feed-forward dialect rather than true
    HLO text (grammar documented in the shim's module docs). The
    experts are exactly dense/relu/.../sigmoid stacks, so the dialect
    is lossless for them; the manifest points the rust runtime at
    these files, while the true HLO text is still written alongside
    for environments with real bindings.
    """
    lines = ["muse-sim-hlo v1", f"input {batch} {d}"]
    width = d
    for li, (w, b) in enumerate(params):
        w = np.asarray(w, dtype=np.float32)
        b = np.asarray(b, dtype=np.float32)
        din, dout = w.shape
        lines.append(f"dense {din} {dout}")
        for o in range(dout):
            # Shim layout: one output unit per row (weights row-major
            # [out][in]); jax params are [in, out], hence the column.
            lines.append(" ".join(repr(float(v)) for v in w[:, o]))
        lines.append(" ".join(repr(float(v)) for v in b))
        lines.append("relu" if li < len(params) - 1 else "sigmoid")
        width = dout
    lines.append(f"output {width}")
    return "\n".join(lines) + "\n"


def lower_transform(k: int, batch: int, n_points: int = QUANTILE_POINTS) -> str:
    """Lower the fused transform pipeline (generic: grids are inputs)."""

    def fn(scores, betas, weights, src_q, ref_q):
        return (tkern.fused_transform(scores, betas, weights, src_q, ref_q),)

    specs = (
        jax.ShapeDtypeStruct((batch, k), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.float32),
        jax.ShapeDtypeStruct((n_points,), jnp.float32),
        jax.ShapeDtypeStruct((n_points,), jnp.float32),
    )
    return to_hlo_text(jax.jit(fn).lower(*specs))


def build_datasets(data_dir: str, force: bool = False) -> list[dict]:
    """Write the binary evaluation datasets consumed by the rust side."""
    os.makedirs(data_dir, exist_ok=True)
    entries = []
    for name, tenant, n, seed, drift in DATASETS:
        path = os.path.join(data_dir, f"{name}.bin")
        if force or not os.path.exists(path):
            if tenant is None:
                x, y = datagen.generate_training_pool(n, seed)
            elif tenant == "m3pool":
                # m3's in-distribution validation: the P1-heavy pool.
                x, y = datagen.generate_training_pool(n, seed, pattern1_frac=0.85)
            else:
                x, y = datagen.generate(n, seed, tenant, drift=drift)
            datagen.write_dataset(path, x, y)
            print(f"[data] {name}: n={n} fraud_rate={float(np.mean(y)):.4f}")
        entries.append(
            {"name": name, "path": f"data/{name}.bin", "n": n, "seed": seed}
        )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--batches", type=int, nargs="*", default=BATCH_VARIANTS,
        help="batch-size variants to lower per expert",
    )
    args = ap.parse_args()

    out = args.out_dir
    models_dir = os.path.join(out, "models")
    transform_dir = os.path.join(out, "transform")
    weights_dir = os.path.join(out, "weights")
    data_dir = os.path.join(out, "data")
    for d in (out, models_dir, transform_dir, weights_dir, data_dir):
        os.makedirs(d, exist_ok=True)

    metas = train.train_all(weights_dir, force=args.force)

    model_entries = []
    for meta in metas:
        name = meta["name"]
        params, _ = train.load_params(weights_dir, name)
        variants = {}
        for b in args.batches:
            path = os.path.join(models_dir, f"{name}_b{b}.hlo.txt")
            if args.force or not os.path.exists(path):
                text = lower_expert(params, b)
                with open(path, "w") as f:
                    f.write(text)
                print(f"[aot] {name} b={b}: {len(text)} chars")
            # The manifest points the runtime at the sim-dialect file
            # (the vendored offline xla shim rejects true HLO text);
            # the .hlo.txt above is kept for real-bindings setups.
            sim_path = os.path.join(models_dir, f"{name}_b{b}.sim.txt")
            if args.force or not os.path.exists(sim_path):
                sim = to_sim_text(params, b, datagen.FEATURE_DIM)
                with open(sim_path, "w") as f:
                    f.write(sim)
                print(f"[aot] {name} b={b}: sim dialect ({len(sim)} chars)")
            variants[str(b)] = f"models/{name}_b{b}.sim.txt"
        model_entries.append(
            {
                "name": name,
                "arch": meta["arch"],
                "beta": meta["beta"],
                "feature_dim": datagen.FEATURE_DIM,
                "batches": variants,
                "weights": f"weights/{name}.json",
                "train_pool_auc": meta.get("train_pool_auc"),
            }
        )

    transform_entries = []
    for k in TRANSFORM_KS:
        for b in TRANSFORM_BATCHES:
            path = os.path.join(transform_dir, f"transform_k{k}_b{b}.hlo.txt")
            if args.force or not os.path.exists(path):
                text = lower_transform(k, b)
                with open(path, "w") as f:
                    f.write(text)
                print(f"[aot] transform k={k} b={b}: {len(text)} chars")
            transform_entries.append(
                {
                    "k": k,
                    "batch": b,
                    "n_points": QUANTILE_POINTS,
                    "path": f"transform/transform_k{k}_b{b}.hlo.txt",
                }
            )

    dataset_entries = build_datasets(data_dir, force=args.force)

    # Cross-language numeric probe: a fixed feature batch plus the
    # python-side expected scores per expert. The rust test suite
    # replays it through the PJRT containers and asserts allclose —
    # this is the guard that caught (and now prevents) constant-elision
    # style interchange bugs.
    probe_path = os.path.join(out, "probe.json")
    rng = np.random.default_rng(20_260_710)
    probe_x = rng.normal(size=(8, datagen.FEATURE_DIM)).astype(np.float32)
    from . import model as model_mod

    expected = {}
    for meta in metas:
        params, _ = train.load_params(weights_dir, meta["name"])
        expected[meta["name"]] = np.asarray(
            model_mod.expert_fwd_ref(jnp.asarray(probe_x), params)
        ).tolist()
    with open(probe_path, "w") as f:
        json.dump(
            {
                "features": probe_x.flatten().tolist(),
                "n": probe_x.shape[0],
                "d": probe_x.shape[1],
                "expected": expected,
            },
            f,
        )

    manifest = {
        "version": 1,
        "feature_dim": datagen.FEATURE_DIM,
        "fraud_prior": datagen.FRAUD_PRIOR,
        "quantile_points": QUANTILE_POINTS,
        "batch_variants": args.batches,
        "models": model_entries,
        "transforms": transform_entries,
        "datasets": dataset_entries,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] manifest: {len(model_entries)} models, "
          f"{len(transform_entries)} transforms, {len(dataset_entries)} datasets")


if __name__ == "__main__":
    main()
