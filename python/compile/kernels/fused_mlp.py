"""L1 Pallas kernel: fused MLP forward (the inference hot-spot).

The paper's experts run on NVIDIA Triton; re-thought for the TPU model
(see DESIGN.md §Hardware adaptation): the batch dimension is tiled via
the grid + ``BlockSpec`` so each grid step holds one ``[block_b, D]``
activation tile plus the full (small) weight set in VMEM and performs
whole-tile matmuls on the MXU. All layers, the bias adds, the relu and
the sigmoid head fuse into a single kernel — one HBM round-trip per
tile instead of one per layer.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret-mode lowers to plain HLO so the same
artifact runs under the rust PJRT CPU client. On a real TPU the same
kernel compiles to Mosaic unchanged (minus ``interpret``).

VMEM budget (f32, defaults D=24, H=64, block_b=64):
  x tile 64*24*4 = 6 KiB, h tile 64*64*4 = 16 KiB,
  weights 24*64*4 + 64*64*4 + 64*4*2 ≈ 22.5 KiB  -> ≪ 16 MiB VMEM,
so double buffering of input tiles is free and the kernel is
MXU-latency bound, not memory bound.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel_1h(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One hidden layer: sigmoid(relu(x@w1+b1)@w2+b2)."""
    x = x_ref[...]
    h = jnp.maximum(x @ w1_ref[...] + b1_ref[...][None, :], 0.0)
    logits = h @ w2_ref[...] + b2_ref[...][None, :]
    o_ref[...] = jnp.reciprocal(1.0 + jnp.exp(-logits[:, 0]))


def _kernel_2h(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    """Two hidden layers: sigmoid(relu(relu(x@w1+b1)@w2+b2)@w3+b3)."""
    x = x_ref[...]
    h1 = jnp.maximum(x @ w1_ref[...] + b1_ref[...][None, :], 0.0)
    h2 = jnp.maximum(h1 @ w2_ref[...] + b2_ref[...][None, :], 0.0)
    logits = h2 @ w3_ref[...] + b3_ref[...][None, :]
    o_ref[...] = jnp.reciprocal(1.0 + jnp.exp(-logits[:, 0]))


def _block_b(batch: int, requested: int) -> int:
    """Largest tile <= requested that divides the batch."""
    b = min(requested, batch)
    while batch % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_b",))
def fused_mlp(x, params, *, block_b: int = 64):
    """Fused forward for a 1- or 2-hidden-layer MLP.

    ``x`` is ``[B, D]`` float32; ``params`` is a list of ``(w, b)``
    pairs (2 pairs = one hidden layer, 3 pairs = two). Returns
    probabilities ``[B]``. Matches ``ref.mlp_ref`` to f32 tolerance.
    """
    batch, d = x.shape
    if len(params) == 2:
        kernel, flat = _kernel_1h, [p for wb in params for p in wb]
    elif len(params) == 3:
        kernel, flat = _kernel_2h, [p for wb in params for p in wb]
    else:
        raise ValueError(f"fused_mlp supports 1 or 2 hidden layers, got {len(params) - 1}")

    bb = _block_b(batch, block_b)
    grid = (batch // bb,)
    # Activations are tiled over the grid; weights are broadcast whole
    # (index_map pinning block 0) so they stay resident in VMEM.
    x_spec = pl.BlockSpec((bb, d), lambda i: (i, 0))
    w_specs = []
    for w, b in params:
        w_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        w_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))
    out_spec = pl.BlockSpec((bb,), lambda i: (i,))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec] + w_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.float32),
        interpret=True,
    )(x, *flat)
