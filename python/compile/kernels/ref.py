"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here
written with plain ``jax.numpy`` ops. pytest (python/tests) asserts
``assert_allclose`` between kernel and oracle across hypothesis-swept
shapes, dtypes and seeds; the oracles are also what ``model.py`` uses
for training (training does not need the kernels' tiling).
"""

from __future__ import annotations

import jax.numpy as jnp


def mlp_ref(x, params):
    """Forward pass of an L-layer MLP: relu hidden layers, sigmoid head.

    ``params`` is a list of ``(w, b)`` pairs; ``x`` is ``[B, D]``.
    Returns probabilities ``[B]``.
    """
    h = x
    for w, b in params[:-1]:
        h = jnp.maximum(h @ w + b, 0.0)
    w, b = params[-1]
    logits = h @ w + b
    return jnp.squeeze(jnp.reciprocal(1.0 + jnp.exp(-logits)), axis=-1)


def mlp_logits_ref(x, params):
    """Same as :func:`mlp_ref` but returning pre-sigmoid logits ``[B]``."""
    h = x
    for w, b in params[:-1]:
        h = jnp.maximum(h @ w + b, 0.0)
    w, b = params[-1]
    return jnp.squeeze(h @ w + b, axis=-1)


def posterior_correction_ref(s, beta):
    """Eq. (3): T^C(s) = beta s / (1 - (1 - beta) s).

    Reverses the posterior bias introduced by undersampling the
    negative class at rate ``beta`` during training. Broadcasts over
    any shape; ``beta`` may be scalar or per-expert ``[K]``.
    """
    return beta * s / (1.0 - (1.0 - beta) * s)


def aggregate_ref(c, weights):
    """Weighted-average aggregation A over expert axis (-1).

    ``c`` is ``[..., K]`` calibrated scores, ``weights`` is ``[K]``.
    """
    w = jnp.asarray(weights)
    return (c * w).sum(axis=-1) / w.sum()


def quantile_map_ref(s, src_q, ref_q):
    """Eq. (4): piecewise-linear quantile mapping T^Q.

    ``src_q`` and ``ref_q`` are monotone quantile grids ``[N+1]``
    (``src_q[0]``/``src_q[N]`` are the support bounds). Scores outside
    the source support clamp to the reference bounds. Vectorized
    rank-then-lerp; matches the rust implementation to f32 tolerance.
    """
    s = jnp.asarray(s)
    n = src_q.shape[0] - 1
    sc = jnp.clip(s, src_q[0], src_q[n])
    # i such that src_q[i] <= s < src_q[i+1]
    idx = jnp.clip(jnp.searchsorted(src_q, sc, side="right") - 1, 0, n - 1)
    q0 = src_q[idx]
    q1 = src_q[idx + 1]
    r0 = ref_q[idx]
    r1 = ref_q[idx + 1]
    denom = jnp.where(q1 > q0, q1 - q0, 1.0)
    t = jnp.where(q1 > q0, (sc - q0) / denom, 0.0)
    return r0 + t * (r1 - r0)


def transform_pipeline_ref(scores, betas, weights, src_q, ref_q):
    """Full MUSE transformation DAG for an ensemble: T^C -> A -> T^Q.

    ``scores`` is ``[B, K]`` raw expert scores. Returns ``[B]``
    business-ready scores following the reference distribution.
    """
    c = posterior_correction_ref(scores, jnp.asarray(betas)[None, :])
    agg = aggregate_ref(c, weights)
    return quantile_map_ref(agg, src_q, ref_q)
