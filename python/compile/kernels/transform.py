"""L1 Pallas kernel: fused score-transformation pipeline T^C -> A -> T^Q.

The paper runs its transformations as "lightweight operations" in the
stateless orchestration app. For batched/offline paths (shadow replays,
backfills, quantile refits over millions of scores) the whole DAG is
worth fusing into one kernel:

* Posterior Correction (Eq. 3) — elementwise rational map, VPU work;
* weighted aggregation A — a reduction over the expert axis K;
* Quantile Mapping (Eq. 4) — the paper does an O(log N) binary search
  per score; per DESIGN.md §Hardware adaptation we instead keep the
  whole (N+1)-point quantile table resident in VMEM and compute the
  rank with a branch-free vectorized comparison-sum, which maps onto
  the VPU's 8x128 lanes far better than a data-dependent search.

VMEM: a [block_b, N+1] comparison tile at block_b=64, N=1024 is
64*1025*4 ≈ 256 KiB — comfortably resident. The kernel is compute-
bound on the comparison sum: ~N+1 lane-ops per score.

``interpret=True`` as everywhere (CPU PJRT cannot run Mosaic); the
rust hot path implements the same math natively for single events and
uses this artifact for batched replays.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(s_ref, beta_ref, w_ref, sq_ref, rq_ref, o_ref):
    s = s_ref[...]  # [bb, K] raw expert scores
    beta = beta_ref[...][None, :]  # [1, K]
    w = w_ref[...]  # [K]
    sq = sq_ref[...]  # [N+1] source quantiles (monotone)
    rq = rq_ref[...]  # [N+1] reference quantiles

    # --- T^C: posterior correction (Eq. 3), elementwise ---
    c = beta * s / (1.0 - (1.0 - beta) * s)

    # --- A: weighted average over experts ---
    agg = (c * w[None, :]).sum(axis=-1) / w.sum()  # [bb]

    # --- T^Q: quantile mapping (Eq. 4), vectorized rank + lerp ---
    n = sq.shape[0] - 1
    aggc = jnp.clip(agg, sq[0], sq[n])
    # rank i with sq[i] <= y < sq[i+1]; branch-free comparison sum.
    cmp = sq[None, :] <= aggc[:, None]  # [bb, N+1]
    idx = jnp.clip(cmp.sum(axis=-1) - 1, 0, n - 1)
    q0 = jnp.take(sq, idx)
    q1 = jnp.take(sq, idx + 1)
    r0 = jnp.take(rq, idx)
    r1 = jnp.take(rq, idx + 1)
    denom = jnp.where(q1 > q0, q1 - q0, 1.0)
    t = jnp.where(q1 > q0, (aggc - q0) / denom, 0.0)
    o_ref[...] = r0 + t * (r1 - r0)


def _block_b(batch: int, requested: int) -> int:
    b = min(requested, batch)
    while batch % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_b",))
def fused_transform(scores, betas, weights, src_q, ref_q, *, block_b: int = 64):
    """Apply the full MUSE transformation DAG to a batch of raw scores.

    ``scores`` ``[B, K]`` float32 raw expert outputs; ``betas``/
    ``weights`` ``[K]``; ``src_q``/``ref_q`` ``[N+1]`` monotone quantile
    grids. Returns business-ready scores ``[B]`` following the
    reference distribution. Matches ``ref.transform_pipeline_ref``.
    """
    batch, k = scores.shape
    nq = src_q.shape[0]
    bb = _block_b(batch, block_b)
    grid = (batch // bb,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((nq,), lambda i: (0,)),
            pl.BlockSpec((nq,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.float32),
        interpret=True,
    )(scores, betas, weights, src_q, ref_q)
