"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

This is the core correctness signal of the compile path: hypothesis
sweeps shapes and seeds, and every kernel output must match its
``ref.py`` oracle to f32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import fused_mlp, ref, transform

RTOL = 3e-5
ATOL = 3e-6


def _params(key, dims):
    params = []
    for din, dout in zip(dims[:-1], dims[1:]):
        key, wk, bk = jax.random.split(key, 3)
        params.append(
            (
                jax.random.normal(wk, (din, dout), jnp.float32) * 0.3,
                jax.random.normal(bk, (dout,), jnp.float32) * 0.1,
            )
        )
    return params


# ---------------------------------------------------------------------------
# fused_mlp
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 130),
    d=st.integers(2, 40),
    h=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_mlp1_matches_ref(batch, d, h, seed):
    key = jax.random.PRNGKey(seed)
    params = _params(key, [d, h, 1])
    x = jax.random.normal(jax.random.fold_in(key, 1), (batch, d), jnp.float32)
    got = fused_mlp.fused_mlp(x, params)
    want = ref.mlp_ref(x, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    batch=st.integers(1, 96),
    d=st.integers(2, 32),
    h=st.integers(2, 40),
    h2=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_mlp2_matches_ref(batch, d, h, h2, seed):
    key = jax.random.PRNGKey(seed)
    params = _params(key, [d, h, h2, 1])
    x = jax.random.normal(jax.random.fold_in(key, 2), (batch, d), jnp.float32)
    got = fused_mlp.fused_mlp(x, params)
    want = ref.mlp_ref(x, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


def test_fused_mlp_rejects_depth():
    x = jnp.zeros((4, 3))
    params = _params(jax.random.PRNGKey(0), [3, 4, 4, 4, 1])
    with pytest.raises(ValueError):
        fused_mlp.fused_mlp(x, params)


@pytest.mark.parametrize("batch", [1, 7, 64, 256])
def test_fused_mlp_block_divisibility(batch):
    """Every batch size must work regardless of the default tile."""
    key = jax.random.PRNGKey(3)
    params = _params(key, [8, 16, 1])
    x = jax.random.normal(key, (batch, 8), jnp.float32)
    got = fused_mlp.fused_mlp(x, params)
    assert got.shape == (batch,)
    want = ref.mlp_ref(x, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


def test_fused_mlp_outputs_are_probabilities():
    key = jax.random.PRNGKey(4)
    params = _params(key, [12, 24, 1])
    x = 10.0 * jax.random.normal(key, (64, 12), jnp.float32)
    got = np.asarray(fused_mlp.fused_mlp(x, params))
    assert np.all(got >= 0.0) and np.all(got <= 1.0)


# ---------------------------------------------------------------------------
# fused_transform
# ---------------------------------------------------------------------------


def _grids(key, n_points):
    src = jnp.sort(jax.random.uniform(key, (n_points,), jnp.float32))
    src = src.at[0].set(0.0).at[-1].set(1.0)
    p = jnp.linspace(0.0, 1.0, n_points, dtype=jnp.float32)
    refq = p**2.0  # arbitrary monotone reference
    return src, refq


@settings(max_examples=20, deadline=None)
@given(
    batch=st.integers(1, 140),
    k=st.integers(1, 9),
    n_points=st.integers(3, 600),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_transform_matches_ref(batch, k, n_points, seed):
    key = jax.random.PRNGKey(seed)
    s = jax.random.uniform(
        jax.random.fold_in(key, 1), (batch, k), jnp.float32, 1e-4, 1.0 - 1e-4
    )
    betas = jax.random.uniform(jax.random.fold_in(key, 2), (k,), jnp.float32, 0.01, 1.0)
    w = jax.random.uniform(jax.random.fold_in(key, 3), (k,), jnp.float32, 0.1, 2.0)
    src, refq = _grids(jax.random.fold_in(key, 4), n_points)
    got = transform.fused_transform(s, betas, w, src, refq)
    want = ref.transform_pipeline_ref(s, betas, w, src, refq)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_fused_transform_clamps_out_of_support():
    """Scores outside the source support map to the reference bounds."""
    src = jnp.linspace(0.2, 0.8, 65)
    refq = jnp.linspace(0.0, 1.0, 65)
    s = jnp.array([[0.0], [0.1], [0.9], [1.0]], jnp.float32)
    betas = jnp.array([1.0])
    w = jnp.array([1.0])
    got = np.asarray(transform.fused_transform(s, betas, w, src, refq))
    np.testing.assert_allclose(got[:2], [0.0, 0.0], atol=1e-6)
    np.testing.assert_allclose(got[2:], [1.0, 1.0], atol=1e-6)


def test_fused_transform_beta_one_identity_correction():
    """beta = 1 (no undersampling) makes T^C the identity."""
    key = jax.random.PRNGKey(7)
    s = jax.random.uniform(key, (64, 1), jnp.float32, 0.0, 1.0)
    src = jnp.linspace(0.0, 1.0, 129)
    refq = src  # identity mapping
    got = transform.fused_transform(s, jnp.array([1.0]), jnp.array([1.0]), src, refq)
    np.testing.assert_allclose(np.asarray(got)[:, None], np.asarray(s), rtol=1e-5, atol=1e-6)
