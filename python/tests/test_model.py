"""L2 model tests: shapes, gradients, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datagen, model


@pytest.mark.parametrize("arch,n_layers", [("mlp1", 2), ("mlp2", 3)])
def test_init_shapes(arch, n_layers):
    p = model.init_params(jax.random.PRNGKey(0), arch, 24, 64, 32)
    assert len(p) == n_layers
    assert p[0][0].shape[0] == 24
    assert p[-1][0].shape[1] == 1


def test_init_unknown_arch():
    with pytest.raises(ValueError):
        model.init_params(jax.random.PRNGKey(0), "tree", 24)


def test_fwd_matches_between_kernel_and_ref():
    key = jax.random.PRNGKey(1)
    p = model.init_params(key, "mlp1", 24, 32)
    x = jax.random.normal(key, (64, 24), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(model.expert_fwd(x, p)),
        np.asarray(model.expert_fwd_ref(x, p)),
        rtol=3e-5,
        atol=3e-6,
    )


def test_bce_loss_is_finite_and_positive():
    key = jax.random.PRNGKey(2)
    p = model.init_params(key, "mlp2", 24, 32, 16)
    x = jax.random.normal(key, (128, 24), jnp.float32)
    y = (jax.random.uniform(key, (128,)) < 0.3).astype(jnp.float32)
    loss = float(model.bce_loss(p, x, y))
    assert np.isfinite(loss) and loss > 0


def test_train_step_decreases_loss():
    key = jax.random.PRNGKey(3)
    x, y = datagen.generate(4096, 5, datagen.TRAIN_TENANTS[0])
    x, y = jnp.asarray(x), jnp.asarray(y)
    p = model.init_params(key, "mlp1", datagen.FEATURE_DIM, 32)
    opt = model.adam_init(p)
    l0 = float(model.bce_loss(p, x, y))
    for _ in range(60):
        p, opt, _ = model.train_step(p, opt, x, y)
    l1 = float(model.bce_loss(p, x, y))
    assert l1 < l0 * 0.9, f"loss did not improve: {l0} -> {l1}"


def test_fit_learns_separation():
    """A short fit must beat chance AUC on held-out data."""
    from compile.train import _auc

    x, y = datagen.generate_training_pool(30_000, 123)
    xu, yu = datagen.undersample(x, y, 0.2, seed=9)
    p = model.init_params(jax.random.PRNGKey(6), "mlp1", datagen.FEATURE_DIM, 32)
    p, _ = model.fit(p, jnp.asarray(xu), jnp.asarray(yu), steps=150, batch=256, seed=1)
    xh, yh = datagen.generate_training_pool(20_000, 456)
    probs = np.asarray(model.expert_fwd_ref(jnp.asarray(xh), p))
    assert _auc(probs, yh) > 0.85


def test_ensemble_fwd_shape():
    key = jax.random.PRNGKey(7)
    ps = [model.init_params(jax.random.fold_in(key, i), "mlp1", 24, 16) for i in range(3)]
    x = jax.random.normal(key, (32, 24), jnp.float32)
    out = model.ensemble_fwd_ref(x, ps)
    assert out.shape == (32, 3)
    assert np.all((np.asarray(out) >= 0) & (np.asarray(out) <= 1))


def test_undersampling_biases_scores_upward():
    """The phenomenon MUSE corrects: smaller beta => inflated scores.

    Train the same architecture on the same pool at beta = 1.0 and
    beta = 0.05; the undersampled model's mean score on legit traffic
    must be clearly higher (Section 2.3.1).
    """
    x, y = datagen.generate_training_pool(40_000, 99)
    p_full = model.init_params(jax.random.PRNGKey(10), "mlp1", datagen.FEATURE_DIM, 32)
    p_us = model.init_params(jax.random.PRNGKey(10), "mlp1", datagen.FEATURE_DIM, 32)
    p_full, _ = model.fit(p_full, jnp.asarray(x), jnp.asarray(y), 200, 256, seed=2)
    xu, yu = datagen.undersample(x, y, 0.05, seed=3)
    p_us, _ = model.fit(p_us, jnp.asarray(xu), jnp.asarray(yu), 200, 256, seed=2)
    legit = jnp.asarray(x[y == 0][:10_000])
    mean_full = float(model.expert_fwd_ref(legit, p_full).mean())
    mean_us = float(model.expert_fwd_ref(legit, p_us).mean())
    assert mean_us > 2.0 * mean_full, (mean_full, mean_us)
