"""AOT path tests: lowering produces loadable HLO text; manifest sanity.

The full artifact tree is built by ``make artifacts``; these tests
validate the lowering helpers on tiny modules (fast) and, when the
artifact tree exists, check manifest/file consistency.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, datagen, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_expert_emits_entry():
    p = model.init_params(jax.random.PRNGKey(0), "mlp1", datagen.FEATURE_DIM, 8)
    text = aot.lower_expert(p, 4)
    assert "ENTRY" in text and "HloModule" in text
    # Weights must be baked in: a constant with the hidden dim appears.
    assert f"f32[{datagen.FEATURE_DIM},8]" in text


def test_lower_expert_batch_shape():
    p = model.init_params(jax.random.PRNGKey(1), "mlp1", datagen.FEATURE_DIM, 8)
    text = aot.lower_expert(p, 16)
    assert f"f32[16,{datagen.FEATURE_DIM}]" in text


def test_lower_transform_emits_entry():
    text = aot.lower_transform(2, 8, n_points=17)
    assert "ENTRY" in text
    assert "f32[8,2]" in text and "f32[17]" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestManifest:
    @pytest.fixture(autouse=True)
    def _load(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            self.m = json.load(f)

    def test_models_present(self):
        names = {e["name"] for e in self.m["models"]}
        assert {"m1", "m2", "m3"} <= names
        assert len(names) == 8  # the Fig. 4 ensemble roster

    def test_every_artifact_file_exists(self):
        for e in self.m["models"]:
            for path in e["batches"].values():
                assert os.path.exists(os.path.join(ART, path)), path
        for t in self.m["transforms"]:
            assert os.path.exists(os.path.join(ART, t["path"]))
        for d in self.m["datasets"]:
            assert os.path.exists(os.path.join(ART, d["path"]))

    def test_betas_match_paper_roster(self):
        betas = {e["name"]: e["beta"] for e in self.m["models"]}
        assert betas["m1"] == pytest.approx(0.18)
        assert betas["m2"] == pytest.approx(0.18)
        assert betas["m3"] == pytest.approx(0.02)

    def test_batch_variants(self):
        for e in self.m["models"]:
            assert set(e["batches"].keys()) == {str(b) for b in self.m["batch_variants"]}

    def test_experts_learned(self):
        for e in self.m["models"]:
            assert e["train_pool_auc"] > 0.85, e["name"]

    def test_quantile_points(self):
        assert self.m["quantile_points"] == 1025
