"""Properties of the transformation oracles (Eqs. 3-4 of the paper)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref

# ---------------------------------------------------------------------------
# Posterior Correction (Eq. 3)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    s=st.floats(0.0, 1.0, allow_nan=False),
    beta=st.floats(0.01, 1.0, allow_nan=False),
)
def test_posterior_correction_range(s, beta):
    # f32 arithmetic (jax x64 disabled) can overshoot 1.0 by ~1 ULP.
    c = float(ref.posterior_correction_ref(jnp.float32(s), beta))
    assert -1e-6 <= c <= 1.0 + 1e-6


@settings(max_examples=50, deadline=None)
@given(beta=st.floats(0.01, 1.0, allow_nan=False))
def test_posterior_correction_fixed_points(beta):
    # jax defaults to f32 (x64 disabled), so allow f32 rounding slack.
    assert abs(float(ref.posterior_correction_ref(jnp.float32(0.0), beta))) < 1e-7
    assert abs(float(ref.posterior_correction_ref(jnp.float32(1.0), beta)) - 1.0) < 1e-5


def test_posterior_correction_identity_at_beta_one():
    s = jnp.linspace(0.0, 1.0, 101)
    np.testing.assert_allclose(
        np.asarray(ref.posterior_correction_ref(s, 1.0)), np.asarray(s), atol=1e-7
    )


@settings(max_examples=30, deadline=None)
@given(beta=st.floats(0.01, 0.99, allow_nan=False), seed=st.integers(0, 10_000))
def test_posterior_correction_strictly_monotone(beta, seed):
    s = np.sort(np.random.default_rng(seed).uniform(0, 1, 64))
    c = np.asarray(ref.posterior_correction_ref(jnp.asarray(s, jnp.float64), beta))
    assert np.all(np.diff(c) >= 0)


def test_posterior_correction_shrinks_scores_for_small_beta():
    """Undersampling inflates scores; the correction deflates them."""
    s = jnp.asarray(np.linspace(0.05, 0.95, 19), jnp.float64)
    c = np.asarray(ref.posterior_correction_ref(s, 0.02))
    assert np.all(c < np.asarray(s))


def test_posterior_correction_matches_prior_algebra():
    """Eq. 3 is the exact inverse of the prior-shift under undersampling.

    If the true posterior is p, training on data where negatives are
    kept with probability beta yields the biased posterior
    p' = p / (p + beta (1 - p)). T^C must recover p from p'.
    """
    p = np.linspace(0.001, 0.999, 201)
    for beta in (0.02, 0.18, 0.5):
        biased = p / (p + beta * (1 - p))
        rec = np.asarray(ref.posterior_correction_ref(jnp.asarray(biased), beta))
        np.testing.assert_allclose(rec, p, rtol=5e-4)  # f32 arithmetic


# ---------------------------------------------------------------------------
# Quantile Mapping (Eq. 4)
# ---------------------------------------------------------------------------


def _monotone_grid(seed, n_points, lo=0.0, hi=1.0):
    rng = np.random.default_rng(seed)
    g = np.sort(rng.uniform(lo, hi, n_points))
    g[0], g[-1] = lo, hi
    # Deduplicate to strictly increasing by nudging.
    for i in range(1, n_points):
        if g[i] <= g[i - 1]:
            g[i] = np.nextafter(g[i - 1], hi)
    return jnp.asarray(g, jnp.float64)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n_points=st.integers(3, 300))
def test_quantile_map_is_monotone(seed, n_points):
    src = _monotone_grid(seed, n_points)
    refq = _monotone_grid(seed + 1, n_points)
    s = jnp.asarray(np.sort(np.random.default_rng(seed).uniform(0, 1, 256)))
    out = np.asarray(ref.quantile_map_ref(s, src, refq))
    assert np.all(np.diff(out) >= -1e-12), "ranking must be preserved (Sec 2.3.3)"


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_quantile_map_hits_knots(seed):
    """Each source quantile must map exactly to its reference quantile."""
    src = _monotone_grid(seed, 65)
    refq = _monotone_grid(seed + 1, 65)
    out = np.asarray(ref.quantile_map_ref(src, src, refq))
    np.testing.assert_allclose(out, np.asarray(refq), rtol=1e-9, atol=1e-12)


def test_quantile_map_identity():
    src = _monotone_grid(3, 33)
    s = jnp.asarray(np.random.default_rng(4).uniform(0, 1, 512))
    out = np.asarray(ref.quantile_map_ref(s, src, src))
    np.testing.assert_allclose(out, np.asarray(s), rtol=1e-9, atol=1e-12)


def test_quantile_map_distribution_alignment():
    """The defining property: mapped samples follow the reference CDF.

    Draw from Beta(2,5), map through quantiles fitted on a large
    sample towards a uniform reference; the result must be ~U(0,1)
    (Kolmogorov-Smirnov distance small).
    """
    rng = np.random.default_rng(11)
    sample = rng.beta(2, 5, 200_000)
    probs = np.linspace(0, 1, 1025)
    src = np.quantile(sample, probs)
    src[0], src[-1] = 0.0, 1.0
    refq = probs  # uniform reference
    fresh = rng.beta(2, 5, 50_000)
    mapped = np.asarray(ref.quantile_map_ref(jnp.asarray(fresh), jnp.asarray(src), jnp.asarray(refq)))
    # empirical CDF vs uniform
    xs = np.sort(mapped)
    ks = np.max(np.abs(xs - np.linspace(0, 1, len(xs))))
    assert ks < 0.01, f"KS distance too large: {ks}"


# ---------------------------------------------------------------------------
# Full pipeline (Eq. 2)
# ---------------------------------------------------------------------------


def test_pipeline_single_model_reduces_to_tq_of_tc():
    """For |M| = 1 with weight 1, Eq. 2 collapses correctly."""
    key = jax.random.PRNGKey(0)
    s = jax.random.uniform(key, (64, 1), jnp.float32, 0.0, 1.0)
    src = _monotone_grid(5, 129).astype(jnp.float32)
    refq = _monotone_grid(6, 129).astype(jnp.float32)
    full = ref.transform_pipeline_ref(s, jnp.array([0.18]), jnp.array([1.0]), src, refq)
    manual = ref.quantile_map_ref(
        ref.posterior_correction_ref(s[:, 0], 0.18), src, refq
    )
    np.testing.assert_allclose(np.asarray(full), np.asarray(manual), rtol=1e-6)


def test_aggregation_weighted_mean():
    c = jnp.asarray([[0.2, 0.4, 0.9]])
    w = jnp.asarray([1.0, 1.0, 2.0])
    got = float(ref.aggregate_ref(c, w)[0])
    assert abs(got - (0.2 + 0.4 + 1.8) / 4.0) < 1e-7
