"""Workload-substrate tests: determinism, imbalance, shift, interchange."""

import os
import tempfile

import numpy as np
import pytest

from compile import datagen


def test_deterministic():
    a = datagen.generate(5000, 42, datagen.CLIENT_A)
    b = datagen.generate(5000, 42, datagen.CLIENT_A)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_shapes_and_dtypes():
    x, y = datagen.generate(1000, 1, datagen.TRAIN_TENANTS[0])
    assert x.shape == (1000, datagen.FEATURE_DIM)
    assert y.shape == (1000,)
    assert x.dtype == np.float32 and y.dtype == np.float32
    assert set(np.unique(y)) <= {0.0, 1.0}


def test_fraud_rate_close_to_prior():
    _, y = datagen.generate(200_000, 7, datagen.TRAIN_TENANTS[1])
    assert abs(y.mean() - datagen.FRAUD_PRIOR) < 0.002


def test_fraud_is_separable():
    """Fraud rows must be shifted along the pattern dims."""
    x, y = datagen.generate(100_000, 8, datagen.TRAIN_TENANTS[0])
    fraud_mean = x[y == 1][:, :8].mean()
    legit_mean = x[y == 0][:, :8].mean()
    assert fraud_mean - legit_mean > 0.5


def test_pattern1_shifts_different_dims():
    t_new = datagen.TenantProfile("t", seed=5, pattern1_frac=1.0)
    t_old = datagen.TenantProfile("t", seed=5, pattern1_frac=0.0)
    xn, yn = datagen.generate(100_000, 9, t_new)
    xo, yo = datagen.generate(100_000, 9, t_old)
    # P1 shifts dims 8..15 strongly; P0 does not.
    d_new = xn[yn == 1][:, 8:16].mean() - xn[yn == 0][:, 8:16].mean()
    d_old = xo[yo == 1][:, 8:16].mean() - xo[yo == 0][:, 8:16].mean()
    assert d_new > 0.8 and d_old < 0.3


def test_tenant_shift_changes_distribution():
    xa, _ = datagen.generate(20_000, 10, datagen.CLIENT_A)
    xt, _ = datagen.generate(20_000, 10, datagen.TRAIN_TENANTS[0])
    # Different affine shifts => clearly different feature means.
    assert np.abs(xa.mean(0) - xt.mean(0)).max() > 0.3


def test_undersample_keeps_all_positives():
    x, y = datagen.generate(50_000, 11, datagen.TRAIN_TENANTS[2])
    xu, yu = datagen.undersample(x, y, 0.1, seed=3)
    assert yu.sum() == y.sum()
    # Negative count ~ beta * original.
    neg = (y == 0).sum()
    negu = (yu == 0).sum()
    assert abs(negu / neg - 0.1) < 0.01


def test_undersample_prior_shift_matches_theory():
    """pi' = pi / (pi + beta (1 - pi)) — the algebra behind Eq. 3."""
    x, y = datagen.generate_training_pool(120_000, 12)
    pi = y.mean()
    for beta in (0.02, 0.18):
        _, yu = datagen.undersample(x, y, beta, seed=4)
        expected = pi / (pi + beta * (1 - pi))
        assert abs(yu.mean() - expected) < 0.01


def test_undersample_rejects_bad_beta():
    x, y = datagen.generate(100, 1, datagen.TRAIN_TENANTS[0])
    with pytest.raises(ValueError):
        datagen.undersample(x, y, 0.0, seed=1)
    with pytest.raises(ValueError):
        datagen.undersample(x, y, 1.5, seed=1)


def test_drift_moves_stream_tail():
    x, _ = datagen.generate(50_000, 13, datagen.CLIENT_A, drift=0.5)
    head = x[:5_000].mean(0)
    tail = x[-5_000:].mean(0)
    assert np.abs(tail - head).max() > 0.1


def test_dataset_roundtrip():
    x, y = datagen.generate(1234, 14, datagen.CLIENT_A)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.bin")
        datagen.write_dataset(path, x, y)
        x2, y2 = datagen.read_dataset(path)
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y, y2)


def test_dataset_header_rejects_garbage():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bad.bin")
        with open(path, "wb") as f:
            f.write(b"\x00" * 64)
        with pytest.raises(ValueError):
            datagen.read_dataset(path)


def test_training_pool_mixes_tenants():
    x, y = datagen.generate_training_pool(60_000, 15)
    assert x.shape == (60_000, datagen.FEATURE_DIM)
    assert 0.01 < y.mean() < 0.02
